//! Property-based tests (hand-rolled — no proptest in the vendored crate
//! set): randomized invariants over the ABFT algebra, the router, the
//! JSON round trip, and the injection planner, with seeds printed on
//! failure for replay.

use ftgemm::abft::checksum::{verify, ChecksumPair, Detection, Thresholds};
use ftgemm::abft::injection::InjectionPlan;
use ftgemm::abft::matrix::Matrix;
use ftgemm::coordinator::router;
use ftgemm::util::json::Json;
use ftgemm::util::rng::Pcg32;
use ftgemm::util::stats::geomean;

const CASES: usize = 60;

/// Tiny property harness: runs `f` for CASES derived seeds, reporting the
/// failing seed.
fn forall(name: &str, f: impl Fn(&mut Pcg32)) {
    for case in 0..CASES {
        let seed = 0xF00D + case as u64 * 7919;
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed:#x}: {e:?}");
        }
    }
}

fn rand_dims(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
    lo + rng.usize_below(hi - lo + 1)
}

// ---------------------------------------------------------------------
// ABFT algebra
// ---------------------------------------------------------------------

#[test]
fn prop_operand_checksums_equal_product_checksums() {
    forall("checksum-identity", |rng| {
        let (m, k, n) = (rand_dims(rng, 1, 40), rand_dims(rng, 1, 60), rand_dims(rng, 1, 40));
        let a = Matrix::rand_uniform(m, k, rng.next_u64());
        let b = Matrix::rand_uniform(k, n, rng.next_u64());
        let fast = ChecksumPair::of_product(&a, &b);
        let direct = ChecksumPair::of(&a.matmul(&b));
        for (x, y) in fast.cr.iter().zip(&direct.cr) {
            assert!((x - y).abs() < 1e-2 + 1e-4 * k as f32, "{x} vs {y}");
        }
        for (x, y) in fast.cc.iter().zip(&direct.cc) {
            assert!((x - y).abs() < 1e-2 + 1e-4 * m as f32, "{x} vs {y}");
        }
    });
}

#[test]
fn prop_single_error_always_located_and_corrected() {
    forall("locate-correct", |rng| {
        let (m, k, n) = (rand_dims(rng, 2, 32), rand_dims(rng, 2, 48), rand_dims(rng, 2, 32));
        let a = Matrix::rand_uniform(m, k, rng.next_u64());
        let b = Matrix::rand_uniform(k, n, rng.next_u64());
        let clean = a.matmul(&b);
        let pair = ChecksumPair::of_product(&a, &b);
        let (row, col) = (rng.usize_below(m), rng.usize_below(n));
        let mag = (rng.f32() + 0.5) * if rng.below(2) == 0 { 100.0 } else { -100.0 };
        let mut bad = clean.clone();
        bad.add_at(row, col, mag);
        match verify(&bad, &pair, Thresholds::default()) {
            Detection::Single { row: r, col: c, magnitude } => {
                assert_eq!((r, c), (row, col));
                assert!((magnitude - mag).abs() < 0.05 * mag.abs() + 0.01);
            }
            other => panic!("expected Single at ({row},{col}) mag {mag}: {other:?}"),
        }
    });
}

#[test]
fn prop_clean_products_never_flag() {
    forall("no-false-positives", |rng| {
        let (m, k, n) = (rand_dims(rng, 1, 48), rand_dims(rng, 1, 96), rand_dims(rng, 1, 48));
        let a = Matrix::rand_uniform(m, k, rng.next_u64());
        let b = Matrix::rand_uniform(k, n, rng.next_u64());
        let c = a.matmul(&b);
        let pair = ChecksumPair::of_product(&a, &b);
        assert_eq!(verify(&c, &pair, Thresholds::default()), Detection::Clean);
    });
}

#[test]
fn prop_pad_slice_roundtrip_preserves_gemm() {
    forall("pad-slice-gemm", |rng| {
        let (m, k, n) = (rand_dims(rng, 1, 30), rand_dims(rng, 1, 30), rand_dims(rng, 1, 30));
        let (pm, pk, pn) =
            (m + rng.usize_below(20), k + rng.usize_below(20), n + rng.usize_below(20));
        let a = Matrix::rand_uniform(m, k, rng.next_u64());
        let b = Matrix::rand_uniform(k, n, rng.next_u64());
        let direct = a.matmul(&b);
        let padded = a.pad_to(pm, pk).matmul(&b.pad_to(pk, pn)).slice_to(m, n);
        assert!(direct.max_abs_diff(&padded) < 1e-3);
    });
}

// ---------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------

#[test]
fn prop_route_covers_output_exactly_once() {
    forall("route-coverage", |rng| {
        let (m, n, k) = (rand_dims(rng, 1, 1400), rand_dims(rng, 1, 1400), rand_dims(rng, 1, 1400));
        let plan = router::route(m, n, k);
        // (row, col) coverage: blocks with k0 == 0 partition the output
        let firsts: Vec<_> = plan.blocks.iter().filter(|b| b.k0 == 0).collect();
        let area: usize = firsts.iter().map(|b| b.m * b.n).sum();
        assert_eq!(area, m * n, "shape {m}x{n}x{k}");
        // k coverage within each (row,col) family
        for f in &firsts {
            let ksum: usize = plan
                .blocks
                .iter()
                .filter(|b| b.row0 == f.row0 && b.col0 == f.col0)
                .map(|b| b.k)
                .sum();
            assert_eq!(ksum, k);
        }
        // every block fits its bucket
        for b in &plan.blocks {
            assert!(b.m <= b.bucket.m && b.n <= b.bucket.n && b.k <= b.bucket.k);
        }
    });
}

/// Brute-force coverage check: within every k-partial (distinct `k0`), the
/// blocks must tile the full output exactly once, and each (row0, col0)
/// family must contain every k-partial.
fn assert_exact_cover(m: usize, n: usize, k: usize) {
    let plan = router::route(m, n, k);
    let mut k0s: Vec<usize> = plan.blocks.iter().map(|b| b.k0).collect();
    k0s.sort_unstable();
    k0s.dedup();
    for &k0 in &k0s {
        let mut cover = vec![0u8; m * n];
        for b in plan.blocks.iter().filter(|b| b.k0 == k0) {
            for i in b.row0..b.row0 + b.m {
                for j in b.col0..b.col0 + b.n {
                    cover[i * n + j] += 1;
                }
            }
        }
        assert!(
            cover.iter().all(|&c| c == 1),
            "{m}x{n}x{k}: k-partial at k0={k0} does not tile the output exactly once"
        );
    }
    // brute-force k_splits: every (row0, col0) family holds every k0
    let mut families: Vec<(usize, usize)> =
        plan.blocks.iter().map(|b| (b.row0, b.col0)).collect();
    families.sort_unstable();
    families.dedup();
    for &(r0, c0) in &families {
        let count = plan.blocks.iter().filter(|b| (b.row0, b.col0) == (r0, c0)).count();
        assert_eq!(count, k0s.len(), "{m}x{n}x{k}: family ({r0},{c0})");
        let ksum: usize = plan
            .blocks
            .iter()
            .filter(|b| (b.row0, b.col0) == (r0, c0))
            .map(|b| b.k)
            .sum();
        assert_eq!(ksum, k, "{m}x{n}x{k}: family ({r0},{c0}) k coverage");
    }
    assert_eq!(plan.k_splits(), k0s.len(), "{m}x{n}x{k}: k_splits");
    assert_eq!(plan.blocks.len(), families.len() * k0s.len());
}

#[test]
fn prop_route_exactly_once_per_k_partial() {
    forall("route-exact-cover", |rng| {
        let (m, n, k) =
            (rand_dims(rng, 1, 1300), rand_dims(rng, 1, 1300), rand_dims(rng, 1, 1300));
        assert_exact_cover(m, n, k);
    });
}

#[test]
fn prop_padded_flops_agree_with_brute_force() {
    forall("route-flop-accounting", |rng| {
        let (m, n, k) =
            (rand_dims(rng, 1, 1300), rand_dims(rng, 1, 1300), rand_dims(rng, 1, 1300));
        let plan = router::route(m, n, k);
        // independent tally: walk the blocks, multiply out bucket volumes
        let mut brute = 0.0f64;
        for b in &plan.blocks {
            brute += 2.0 * (b.bucket.m as f64) * (b.bucket.n as f64) * (b.bucket.k as f64);
        }
        assert!((plan.padded_flops() - brute).abs() < 1e-6 * brute.max(1.0));
        assert!((plan.useful_flops() - 2.0 * (m * n * k) as f64).abs() < 1.0);
        // padding can only add work; equality exactly when nothing is padded
        if plan.blocks.iter().all(|b| !b.is_padded()) {
            assert_eq!(plan.padded_flops(), plan.useful_flops(), "{m}x{n}x{k}");
        } else {
            assert!(plan.padded_flops() > plan.useful_flops(), "{m}x{n}x{k}");
        }
    });
}

#[test]
fn prop_irregular_example_shapes_route_correctly() {
    // the shapes examples/irregular_shapes.rs serves live, pinned here with
    // their expected routing outcomes
    use ftgemm::codegen::ShapeClass;
    let cases: &[(usize, usize, usize, ShapeClass, usize, usize)] = &[
        // (m, n, k, bucket class of block 0, blocks, k_splits)
        (31, 17, 53, ShapeClass::Small, 1, 1),
        (64, 64, 64, ShapeClass::Small, 1, 1),
        (100, 90, 70, ShapeClass::Medium, 1, 1),
        (97, 430, 211, ShapeClass::Tall, 1, 1),
        (250, 250, 250, ShapeClass::Large, 1, 1),
        (257, 257, 257, ShapeClass::Huge, 1, 1),
        (640, 640, 640, ShapeClass::Huge, 8, 2),
    ];
    for &(m, n, k, class, blocks, k_splits) in cases {
        let plan = router::route(m, n, k);
        assert_eq!(plan.blocks[0].bucket.class, class, "{m}x{n}x{k}");
        assert_eq!(plan.blocks.len(), blocks, "{m}x{n}x{k}");
        assert_eq!(plan.k_splits(), k_splits, "{m}x{n}x{k}");
        assert_eq!(plan.split, blocks > 1, "{m}x{n}x{k}");
        assert_exact_cover(m, n, k);
    }
}

#[test]
fn prop_planner_emits_one_independent_node_per_block() {
    use ftgemm::coordinator::plan::{NodeOp, Planner};
    use ftgemm::coordinator::{CoordinatorConfig, FtPolicy};
    use ftgemm::runtime::Manifest;

    let manifest = Manifest::builtin();
    let config = CoordinatorConfig::default();
    forall("planner-node-per-block", |rng| {
        let (m, n, k) =
            (rand_dims(rng, 1, 1200), rand_dims(rng, 1, 1200), rand_dims(rng, 1, 1200));
        let route = router::route(m, n, k);
        for policy in [FtPolicy::None, FtPolicy::Online, FtPolicy::Offline] {
            let plan = Planner::new(&manifest, &config)
                .plan_gemm(m, n, k, policy, &ftgemm::abft::injection::InjectionPlan::none())
                .unwrap();
            assert_eq!(plan.nodes.len(), route.blocks.len());
            assert_eq!(plan.roots(), plan.nodes.len(), "block nodes are independent");
            for (node, block) in plan.nodes.iter().zip(&route.blocks) {
                match &node.op {
                    NodeOp::Block { block: nb, .. } => assert_eq!(nb, block),
                    other => panic!("unexpected node {other:?}"),
                }
            }
        }
    });
}

#[test]
fn prop_non_split_requests_use_minimal_waste_bucket() {
    forall("route-waste", |rng| {
        let (m, n, k) = (rand_dims(rng, 1, 512), rand_dims(rng, 1, 512), rand_dims(rng, 1, 512));
        let plan = router::route(m, n, k);
        if !plan.split {
            let chosen = plan.blocks[0].bucket;
            for b in ftgemm::codegen::select::BUCKETS {
                if b.fits(m, n, k) {
                    assert!(
                        chosen.waste(m, n, k) <= b.waste(m, n, k) + 1e-12,
                        "{m}x{n}x{k}: {} not minimal vs {}",
                        chosen.name(),
                        b.name()
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// JSON
// ---------------------------------------------------------------------

fn rand_json(rng: &mut Pcg32, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.f64() * 2e6 - 1e6).round() / 16.0),
        3 => {
            let len = rng.usize_below(12);
            Json::Str(
                (0..len)
                    .map(|_| char::from_u32(0x20 + rng.below(0x7E - 0x20)).unwrap())
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.usize_below(5)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => Json::from_pairs(
            (0..rng.usize_below(5)).map(|i| (format!("k{i}"), rand_json(rng, depth - 1))),
        ),
    }
}

#[test]
fn prop_json_roundtrips() {
    forall("json-roundtrip", |rng| {
        let v = rand_json(rng, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(compact, v);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
    });
}

// ---------------------------------------------------------------------
// Injection planner
// ---------------------------------------------------------------------

#[test]
fn prop_seu_plans_have_unique_protection_domains() {
    forall("seu-domains", |rng| {
        let m = 64 * (1 + rng.usize_below(8));
        let n = 64 * (1 + rng.usize_below(8));
        let steps = 8 * (1 + rng.usize_below(8));
        let (sub_m, sub_n, ve) = (32, 32, 8);
        let domains = (m / sub_m) * (n / sub_n) * steps.div_ceil(ve);
        let count = 1 + rng.usize_below(domains.min(16));
        let plan = InjectionPlan::random_seu(m, n, steps, ve, sub_m, sub_n, count, rng);
        assert_eq!(plan.len(), count);
        let mut seen = std::collections::HashSet::new();
        for e in &plan.injections {
            assert!(e.row < m && e.col < n && e.step < steps);
            assert!(
                seen.insert((e.row / sub_m, e.col / sub_n, e.step / ve)),
                "duplicate protection domain"
            );
        }
    });
}

#[test]
fn prop_chunking_never_loses_injections() {
    forall("chunking", |rng| {
        let count = rng.usize_below(40) + 1;
        let plan = InjectionPlan {
            injections: (0..count)
                .map(|i| ftgemm::abft::injection::Injection {
                    row: i,
                    col: i,
                    step: i,
                    magnitude: 1.0 + i as f32,
                })
                .collect(),
        };
        let max_inj = rng.usize_below(8) + 1;
        let chunks = plan.chunks(max_inj);
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, count);
        assert!(chunks.iter().all(|c| c.len() <= max_inj));
    });
}

// ---------------------------------------------------------------------
// Submission surface (GemmRequest / FtLevel / dispatch ordering)
// ---------------------------------------------------------------------

#[test]
fn prop_ft_level_string_round_trip() {
    use ftgemm::coordinator::FtLevel;
    for level in FtLevel::ALL {
        assert_eq!(level.as_str().parse::<FtLevel>().unwrap(), level);
    }
    forall("ft-level-garbage-rejected", |rng| {
        // random ASCII that is not one of the three spellings must fail
        let len = rng.usize_below(8) + 1;
        let s: String =
            (0..len).map(|_| char::from_u32(0x61 + rng.below(26)).unwrap()).collect();
        match s.as_str() {
            "tb" | "warp" | "thread" => assert!(s.parse::<FtLevel>().is_ok()),
            _ => assert!(s.parse::<FtLevel>().is_err(), "{s:?} should not parse"),
        }
    });
}

/// Randomized version of the integration priority test: under a saturated
/// single-dispatcher coordinator, any shuffle of priorities dequeues
/// sorted by (priority desc, submission order). Few cases — each run
/// holds a real occupier GEMM on the dispatcher.
#[test]
fn prop_saturated_dispatch_order_is_priority_then_fifo() {
    use ftgemm::abft::matrix::Matrix;
    use ftgemm::coordinator::{
        Coordinator, CoordinatorConfig, FtPolicy, GemmRequest, Priority,
    };
    use ftgemm::runtime::{Engine, EngineConfig};

    const PRIORITIES: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];
    for case in 0..4u64 {
        let seed = 0xD15A + case * 7919;
        let mut rng = Pcg32::seeded(seed);
        let engine = Engine::start(EngineConfig::default()).unwrap();
        let coord = Coordinator::new(
            engine,
            CoordinatorConfig { max_inflight: 1, ..Default::default() },
        );
        // hold the only dispatcher on one exact huge-bucket block
        let blocker = coord
            .submit(GemmRequest::new(
                Matrix::rand_uniform(512, 512, seed),
                Matrix::rand_uniform(512, 512, seed + 1),
            ).policy(FtPolicy::None))
            .unwrap();
        let picks: Vec<Priority> =
            (0..8).map(|_| PRIORITIES[rng.usize_below(3)]).collect();
        let tickets: Vec<_> = picks
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let a = Matrix::rand_uniform(64, 64, seed + 10 + i as u64);
                let b = Matrix::rand_uniform(64, 64, seed + 50 + i as u64);
                coord
                    .submit(GemmRequest::new(a, b).policy(FtPolicy::None).priority(p))
                    .unwrap()
            })
            .collect();
        blocker.wait().unwrap();
        let metas: Vec<_> =
            tickets.into_iter().map(|t| t.wait().unwrap().meta).collect();
        // expected dequeue order: priority desc, then submission order
        let mut expect: Vec<usize> = (0..picks.len()).collect();
        expect.sort_by_key(|&i| (std::cmp::Reverse(picks[i]), i));
        let seqs: Vec<u64> = expect.iter().map(|&i| metas[i].dispatch_seq).collect();
        for w in seqs.windows(2) {
            assert!(
                w[0] < w[1],
                "seed {seed:#x}: dispatch order violated priority-then-FIFO \
                 (picks {picks:?}, seqs {seqs:?})"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Sharded dispatch: affinity routing + work stealing
// ---------------------------------------------------------------------

/// Randomized skewed loads across 2–4 engine pools: every submitted
/// request settles exactly once (unique dispatch_seq, one response per
/// ticket), lands on a real pool, and the per-pool routed/dispatched/
/// steal counters reconcile with the coordinator-wide totals.
#[test]
fn prop_sharded_dispatch_executes_exactly_once_and_counters_reconcile() {
    use ftgemm::coordinator::{Coordinator, CoordinatorConfig, FtPolicy, GemmRequest};
    use ftgemm::runtime::{Engine, EngineConfig};

    for case in 0..3u64 {
        let seed = 0x5EA1 + case * 7919;
        let mut rng = Pcg32::seeded(seed);
        let pools = 2 + case as usize; // 2, 3, 4
        let engine =
            Engine::start(EngineConfig { workers: 1, pools, ..Default::default() }).unwrap();
        let coord = Coordinator::new(
            engine,
            CoordinatorConfig {
                max_inflight: pools, // one home dispatcher per pool
                steal_threshold: 1 + rng.usize_below(3),
                ..Default::default()
            },
        );
        // skewed load: mostly one shape class, so the affinity router
        // funnels a burst at one pool and balancing has to spread it
        let n_req = 24usize;
        let tickets: Vec<_> = (0..n_req)
            .map(|i| {
                let size = if rng.below(4) == 0 { 128 } else { 64 };
                let a = Matrix::rand_uniform(size, size, seed + 2 * i as u64);
                let b = Matrix::rand_uniform(size, size, seed + 2 * i as u64 + 1);
                coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap()
            })
            .collect();
        let metas: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap().meta).collect();

        // exactly once: one settled response per ticket, no shared
        // dispatch slot (dispatch_seq is bumped once per dequeue)
        assert_eq!(metas.len(), n_req);
        let mut seqs: Vec<u64> = metas.iter().map(|m| m.dispatch_seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), n_req, "seed {seed:#x}: a dispatch slot was reused");

        let s = coord.stats();
        assert_eq!(s.pools.len(), pools);
        let routed: u64 = s.pools.iter().map(|p| p.routed).sum();
        let dispatched: u64 = s.pools.iter().map(|p| p.dispatched).sum();
        let steals: u64 = s.pools.iter().map(|p| p.steals).sum();
        assert_eq!(routed, n_req as u64, "seed {seed:#x}: routed total");
        assert_eq!(routed, s.counters.requests, "seed {seed:#x}: routed vs requests");
        assert_eq!(dispatched, n_req as u64, "seed {seed:#x}: dispatched total");
        assert_eq!(s.counters.canceled + s.counters.expired, 0);
        assert!(steals <= dispatched);
        for (p, stat) in s.pools.iter().enumerate() {
            assert!(stat.steals <= stat.dispatched, "pool {p} steals exceed dispatched");
        }
        // the pool recorded in each response matches the per-pool
        // dispatched counters (stolen work counts for the thief's pool)
        let mut per_pool = vec![0u64; pools];
        for m in &metas {
            assert!(m.pool < pools, "seed {seed:#x}: meta.pool {} out of range", m.pool);
            per_pool[m.pool] += 1;
        }
        for (p, stat) in s.pools.iter().enumerate() {
            assert_eq!(
                stat.dispatched, per_pool[p],
                "seed {seed:#x}: pool {p} dispatched vs settled metas"
            );
        }
    }
}

/// With the skew threshold effectively infinite, the router never re-pins
/// and idle dispatchers never steal: every request of one shape class
/// runs on its affinity pool and the other pool stays untouched.
#[test]
fn prop_no_steals_below_threshold() {
    use ftgemm::coordinator::{Coordinator, CoordinatorConfig, FtPolicy, GemmRequest};
    use ftgemm::runtime::{Engine, EngineConfig};

    let engine =
        Engine::start(EngineConfig { workers: 1, pools: 2, ..Default::default() }).unwrap();
    let coord = Coordinator::new(
        engine,
        CoordinatorConfig {
            max_inflight: 2,
            steal_threshold: usize::MAX,
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..16u64)
        .map(|i| {
            let a = Matrix::rand_uniform(64, 64, 0xA0 + 2 * i);
            let b = Matrix::rand_uniform(64, 64, 0xA1 + 2 * i);
            coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap()
        })
        .collect();
    let metas: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap().meta).collect();
    let s = coord.stats();
    let steals: u64 = s.pools.iter().map(|p| p.steals).sum();
    assert_eq!(steals, 0, "nothing may be stolen below the skew threshold");
    // one shape class, one affinity pool: the whole burst stays put
    let home = metas[0].pool;
    assert!(metas.iter().all(|m| m.pool == home), "affinity pool changed mid-burst");
    assert_eq!(s.pools[home].routed, 16);
    assert_eq!(s.pools[home].dispatched, 16);
    assert_eq!(s.pools[1 - home].routed, 0);
    assert_eq!(s.pools[1 - home].dispatched, 0);
}

/// Stealing must actually happen once the skew threshold is crossed: one
/// dispatcher is held by a huge blocker while smalls pile onto its pool's
/// queue; the other dispatcher's home queue eventually runs dry while
/// live work remains, so it must steal (or it stole the blocker itself —
/// either way the steal counters move).
#[test]
fn prop_steals_occur_past_threshold_under_skew() {
    use ftgemm::coordinator::{
        Coordinator, CoordinatorConfig, FtPolicy, GemmRequest, TicketStatus,
    };
    use ftgemm::runtime::{Engine, EngineConfig};

    let engine =
        Engine::start(EngineConfig { workers: 1, pools: 2, ..Default::default() }).unwrap();
    let coord = Coordinator::new(
        engine,
        CoordinatorConfig { max_inflight: 2, steal_threshold: 1, ..Default::default() },
    );
    // occupy one dispatcher + one pool's engine worker with a huge block
    let blocker = coord
        .submit(
            GemmRequest::new(
                Matrix::rand_uniform(512, 512, 0xB0),
                Matrix::rand_uniform(512, 512, 0xB1),
            )
            .policy(FtPolicy::None),
        )
        .unwrap();
    // wait until it is actually running so the burst below routes against
    // empty queues (first small pins its class to one pool)
    while blocker.poll() == TicketStatus::Queued {
        std::thread::yield_now();
    }
    let tickets: Vec<_> = (0..10u64)
        .map(|i| {
            let a = Matrix::rand_uniform(64, 64, 0xC0 + 2 * i);
            let b = Matrix::rand_uniform(64, 64, 0xC1 + 2 * i);
            coord.submit(GemmRequest::new(a, b).policy(FtPolicy::None)).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    blocker.wait().unwrap();
    let s = coord.stats();
    let steals: u64 = s.pools.iter().map(|p| p.steals).sum();
    let dispatched: u64 = s.pools.iter().map(|p| p.dispatched).sum();
    assert_eq!(dispatched, 11);
    assert!(
        steals >= 1,
        "a saturated pool with an idle neighbor past the skew threshold must steal \
         (pools: {:?})",
        s.pools
    );
}

// ---------------------------------------------------------------------
// Backend parity: BlockedBackend vs ReferenceBackend
// ---------------------------------------------------------------------

/// Every GEMM-family artifact, clean and injected (SEU-constrained plans,
/// so the fused levels can correct everything), on EVERY kernel variant
/// the host supports (scalar always; AVX2 / AVX-512 / NEON where
/// detected): the blocked backend's outputs — C, carried checksums, and
/// the per-tile errcount grid — are element-wise equal to the reference
/// backend's, with the errcount grid exactly equal (carried checksums
/// are bit-identical across ISAs by the canonical-fold contract, so
/// detection decisions cannot diverge). Covers all three FT levels
/// (tb/warp/thread artifacts), the detect-only kernel, and the
/// verify-interval ablation variants.
#[test]
fn prop_blocked_backend_is_elementwise_equal_to_reference() {
    use ftgemm::runtime::engine::Tensor;
    use ftgemm::runtime::{
        ArtifactKind, Backend, BlockedBackend, KernelIsa, Manifest, ReferenceBackend,
    };

    let man = Manifest::builtin();
    let mut reference = ReferenceBackend::new();
    for isa in KernelIsa::supported() {
        let mut blocked = BlockedBackend::with_threads_isa(4, isa);
        assert_eq!(blocked.kernel_isa(), isa, "host-supported ISA must pin");
        let mut rng = Pcg32::seeded(0xB10C);
        let mut checked = 0usize;
        for art in man.iter() {
            let is_ft = match art.kind {
                ArtifactKind::Gemm => false,
                ArtifactKind::FtGemm | ArtifactKind::FtDetect => true,
                _ => continue, // ding chain covered by the blocked unit tests
            };
            for round in 0..2usize {
                if round == 1 && !is_ft {
                    continue;
                }
                let a = Matrix::rand_uniform(art.m, art.k, rng.next_u64());
                let b = Matrix::rand_uniform(art.k, art.n, rng.next_u64());
                let mut inputs = vec![
                    Tensor::new(vec![art.m, art.k], a.data().to_vec()),
                    Tensor::new(vec![art.k, art.n], b.data().to_vec()),
                ];
                if is_ft {
                    let plan = if round == 0 {
                        InjectionPlan::none()
                    } else {
                        InjectionPlan::random_seu(
                            art.m,
                            art.n,
                            art.k,
                            art.verify_every,
                            art.sub_m,
                            art.sub_n,
                            3,
                            &mut rng,
                        )
                    };
                    inputs
                        .push(Tensor::new(vec![art.max_inj, 4], plan.to_tensor(art.max_inj)));
                }
                let got = blocked.execute(art, inputs.clone()).unwrap();
                let want = reference.execute(art, inputs).unwrap();
                assert_eq!(got.len(), want.len(), "{}", art.name);
                for ((g, w), spec) in got.iter().zip(&want).zip(&art.outputs) {
                    if spec.role == "errcount" {
                        assert_eq!(
                            g.data, w.data,
                            "{} [{}] round {round}: errcount grids diverged",
                            art.name,
                            isa.name()
                        );
                        continue;
                    }
                    let diff = g
                        .data
                        .iter()
                        .zip(&w.data)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f32, f32::max);
                    // C is tight: same fold order, the only slack is the
                    // FMA kernels' fused rounding, growing with k.
                    // Carried checksums are k-length sums of C elements,
                    // so they get k-amplified headroom.
                    let tol =
                        if spec.role == "c" { 1e-3 + 4e-6 * art.k as f32 } else { 0.1 };
                    assert!(
                        diff < tol,
                        "{} [{}] round {round}: output {:?} diverged by {diff}",
                        art.name,
                        isa.name(),
                        spec.role
                    );
                }
                checked += 1;
            }
        }
        assert!(
            checked >= 20,
            "expected to cover the artifact registry on {}, got {checked}",
            isa.name()
        );
    }
}

/// Satellite pin for the KC-blocked loop nest: for every host-supported
/// ISA and every reduction depth KC ∈ {64, 128, k}, the blocked backend
/// pinned to that depth (via `with_kc` — instance-level, so the parallel
/// test harness stays race-free) remains element-wise equal to the
/// reference backend with EXACTLY equal errcount grids, clean and
/// SEU-injected alike. On top of cross-backend parity, every output —
/// C, the carried checksums, and the errcount grid — must be BITWISE
/// identical across the three KC choices on a given ISA: between
/// reduction panels the accumulator tile round-trips through exact f32
/// stores/reloads and the per-KC-panel partial eᵀA/Be sums partition
/// the canonical fold, so splitting the reduction can change nothing.
#[test]
fn prop_kc_blocking_preserves_parity_and_is_bitwise_stable() {
    use ftgemm::runtime::engine::Tensor;
    use ftgemm::runtime::{Backend, BlockedBackend, KernelIsa, Manifest, ReferenceBackend};

    let man = Manifest::builtin();
    let mut reference = ReferenceBackend::new();
    // One artifact per kind/level/shape axis of interest: plain GEMM,
    // the three FT levels, detect-only — mediums exercise ragged KC=64
    // panels (256 % 64 == 0 but KC < k), the huge shape multi-block rows.
    let names =
        ["gemm_medium", "ftgemm_tb_medium", "ftgemm_warp_medium", "ftgemm_thread_huge", "ftdetect_medium"];
    for isa in KernelIsa::supported() {
        let mut rng = Pcg32::seeded(0x6C0DE);
        for name in names {
            let art = man.get(name).unwrap();
            let is_ft = art.max_inj > 0;
            let a = Matrix::rand_uniform(art.m, art.k, rng.next_u64());
            let b = Matrix::rand_uniform(art.k, art.n, rng.next_u64());
            let plan = if is_ft {
                InjectionPlan::random_seu(
                    art.m,
                    art.n,
                    art.k,
                    art.verify_every,
                    art.sub_m,
                    art.sub_n,
                    3,
                    &mut rng,
                )
            } else {
                InjectionPlan::none()
            };
            for clean in [true, false] {
                if !clean && !is_ft {
                    continue;
                }
                let inputs = || {
                    let mut v = vec![
                        Tensor::new(vec![art.m, art.k], a.data().to_vec()),
                        Tensor::new(vec![art.k, art.n], b.data().to_vec()),
                    ];
                    if is_ft {
                        let p = if clean { InjectionPlan::none() } else { plan.clone() };
                        v.push(Tensor::new(vec![art.max_inj, 4], p.to_tensor(art.max_inj)));
                    }
                    v
                };
                let want = reference.execute(art, inputs()).unwrap();
                let mut pinned: Option<Vec<Tensor>> = None;
                for kc in [64usize, 128, art.k] {
                    let mut blocked =
                        BlockedBackend::with_threads_isa(4, isa).with_kc(Some(kc));
                    let got = blocked.execute(art, inputs()).unwrap();
                    for ((g, w), spec) in got.iter().zip(&want).zip(&art.outputs) {
                        if spec.role == "errcount" {
                            assert_eq!(
                                g.data, w.data,
                                "{name} [{}] KC={kc} clean={clean}: errcount grids diverged",
                                isa.name()
                            );
                            continue;
                        }
                        let diff = g
                            .data
                            .iter()
                            .zip(&w.data)
                            .map(|(x, y)| (x - y).abs())
                            .fold(0.0f32, f32::max);
                        let tol =
                            if spec.role == "c" { 1e-3 + 4e-6 * art.k as f32 } else { 0.1 };
                        assert!(
                            diff < tol,
                            "{name} [{}] KC={kc} clean={clean}: {:?} diverged by {diff}",
                            isa.name(),
                            spec.role
                        );
                    }
                    match &pinned {
                        None => pinned = Some(got),
                        Some(first) => {
                            for ((g, f), spec) in got.iter().zip(first).zip(&art.outputs) {
                                assert_eq!(
                                    g.data, f.data,
                                    "{name} [{}] KC={kc} clean={clean}: {:?} not bitwise \
                                     stable across KC",
                                    isa.name(),
                                    spec.role
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

/// The serving-level parity witness: coordinators over a blocked-backend
/// engine and a reference-backend engine agree (and agree with the host
/// matmul) across randomized shapes including the irregular codegen
/// example shapes — padded, tall, split, and injected requests included.
#[test]
fn prop_blocked_coordinator_matches_reference_on_irregular_shapes() {
    use ftgemm::coordinator::{Coordinator, CoordinatorConfig, FtPolicy};
    use ftgemm::runtime::{Engine, EngineConfig};

    let reference = Coordinator::new(
        Engine::start(EngineConfig::default()).unwrap(),
        CoordinatorConfig::default(),
    );
    let blocked = Coordinator::new(
        Engine::start(EngineConfig {
            backend: "blocked".into(),
            workers: 2,
            ..Default::default()
        })
        .unwrap(),
        CoordinatorConfig::default(),
    );
    // the irregular_shapes example sweep, then randomized shapes
    let mut shapes = vec![
        (31usize, 17usize, 53usize),
        (64, 64, 64),
        (100, 90, 70),
        (97, 430, 211),
        (257, 257, 257),
        (640, 640, 640), // oversize -> split across blocks
    ];
    let mut rng = Pcg32::seeded(0xB10C2);
    for _ in 0..5 {
        shapes.push((
            rand_dims(&mut rng, 1, 280),
            rand_dims(&mut rng, 1, 280),
            rand_dims(&mut rng, 1, 280),
        ));
    }
    for (m, n, k) in shapes {
        let a = Matrix::rand_uniform(m, k, rng.next_u64());
        let b = Matrix::rand_uniform(k, n, rng.next_u64());
        let want = a.matmul(&b);
        let tol = 5e-3 * (k as f32).max(1.0) / 64.0 + 1e-3;
        let r = reference.gemm(&a, &b, FtPolicy::Online).unwrap();
        let g = blocked.gemm(&a, &b, FtPolicy::Online).unwrap();
        assert_eq!(r.buckets, g.buckets, "({m},{n},{k}): routed differently");
        let host_diff = g.c.max_abs_diff(&want);
        assert!(host_diff < tol, "({m},{n},{k}): blocked vs host diff {host_diff}");
        let cross = g.c.max_abs_diff(&r.c);
        assert!(cross < tol, "({m},{n},{k}): blocked vs reference diff {cross}");
        // injected request: both backends detect+correct identically
        let inj = InjectionPlan::single(m / 2, n / 2, 0, 4096.0);
        let ri = reference.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
        let gi = blocked.gemm_with_faults(&a, &b, FtPolicy::Online, &inj).unwrap();
        assert_eq!(
            (ri.errors_detected, ri.errors_corrected),
            (gi.errors_detected, gi.errors_corrected),
            "({m},{n},{k}): fault accounting diverged"
        );
        assert!(gi.errors_corrected >= 1, "({m},{n},{k}): injection went uncorrected");
        let diff = gi.c.max_abs_diff(&want);
        assert!(diff < tol + 0.3, "({m},{n},{k}): injected blocked diff {diff}");
    }
}

// ---------------------------------------------------------------------
// Cross-request packed-operand cache
// ---------------------------------------------------------------------

/// Randomized interleaving of pack-cache hits, misses, and evictions on a
/// 2-pool blocked engine with a deliberately tiny (1 MiB) cache budget:
/// every result must be element-wise *identical* to a cache-disabled
/// blocked coordinator — cached panels and checksum sums are bitwise
/// equal to freshly packed ones, so the downstream compute is too — and
/// within tolerance of the host matmul, clean and injected alike, with
/// fault accounting exactly equal. The run must actually exercise the
/// cache: hits, misses, and evictions all observed, and every pool's
/// resident bytes within the configured budget.
#[test]
fn prop_pack_cache_interleaving_preserves_blocked_results() {
    use std::sync::Arc;

    use ftgemm::coordinator::{Coordinator, CoordinatorConfig, FtPolicy, GemmRequest};
    use ftgemm::runtime::{Engine, EngineConfig};

    let cached_engine = Engine::start(EngineConfig {
        backend: "blocked".into(),
        workers: 1,
        pools: 2,
        pack_cache_mb: Some(1), // tiny: distinct operands must evict
        ..Default::default()
    })
    .unwrap();
    let cached = Coordinator::new(cached_engine.clone(), CoordinatorConfig::default());
    let uncached = Coordinator::new(
        Engine::start(EngineConfig {
            backend: "blocked".into(),
            workers: 1,
            pools: 2,
            pack_cache_mb: Some(0),
            ..Default::default()
        })
        .unwrap(),
        CoordinatorConfig::default(),
    );

    let check_round = |round: usize, n: usize, a: &Arc<Matrix>, b: &Arc<Matrix>| {
        let inject = round % 2 == 0;
        let inj = if inject {
            InjectionPlan::single(n / 2, n / 2, 0, 4096.0)
        } else {
            InjectionPlan::none()
        };
        let req = || {
            GemmRequest::new(Arc::clone(a), Arc::clone(b))
                .policy(FtPolicy::Online)
                .inject(inj.clone())
        };
        let got = cached.submit(req()).unwrap().wait().unwrap().result;
        let want = uncached.submit(req()).unwrap().wait().unwrap().result;
        // same backend and ISA, bitwise-identical packed panels and
        // checksum sums: the cached result is exactly the fresh one
        assert_eq!(got.c.max_abs_diff(&want.c), 0.0, "round {round} (n={n})");
        assert_eq!(
            (got.errors_detected, got.errors_corrected),
            (want.errors_detected, want.errors_corrected),
            "round {round} (n={n}): fault accounting diverged"
        );
        if inject {
            assert!(got.errors_corrected >= 1, "round {round} (n={n}): uncorrected");
        }
        let host = a.matmul(b);
        let tol = 5e-3 * (n as f32) / 64.0 + 1e-3 + if inject { 0.3 } else { 0.0 };
        let diff = got.c.max_abs_diff(&host);
        assert!(diff < tol, "round {round} (n={n}): host diff {diff}");
    };

    // a reusable operand pool: resubmitting the same Arcs is a hit, a
    // fresh pair is a miss, and the byte budget forces evictions
    let mut rng = Pcg32::seeded(0xCAC4E);
    let sizes = [64usize, 128, 256];
    let mut ops: Vec<(usize, Arc<Matrix>, Arc<Matrix>)> = Vec::new();
    for round in 0..20usize {
        let (n, a, b) = if !ops.is_empty() && rng.below(2) == 0 {
            let pick = &ops[rng.usize_below(ops.len())];
            (pick.0, Arc::clone(&pick.1), Arc::clone(&pick.2))
        } else {
            let n = sizes[rng.usize_below(sizes.len())];
            let a = Arc::new(Matrix::rand_uniform(n, n, 0xCA00 + 2 * round as u64));
            let b = Arc::new(Matrix::rand_uniform(n, n, 0xCA01 + 2 * round as u64));
            ops.push((n, Arc::clone(&a), Arc::clone(&b)));
            (n, a, b)
        };
        check_round(round, n, &a, &b);
    }
    // deterministic tail: enough distinct 256^3 pairs (~512 KiB of packed
    // panels each) to overflow the 1 MiB per-pool budget regardless of
    // how the random mix above reused, then a guaranteed-resident repeat
    let mut last = None;
    for extra in 0..4u64 {
        let a = Arc::new(Matrix::rand_uniform(256, 256, 0xEE00 + 2 * extra));
        let b = Arc::new(Matrix::rand_uniform(256, 256, 0xEE01 + 2 * extra));
        check_round(100 + extra as usize, 256, &a, &b);
        last = Some((a, b));
    }
    let (a, b) = last.unwrap();
    check_round(200, 256, &a, &b); // just inserted: this repeat must hit

    let stats = cached_engine.pack_cache_stats().expect("cache is enabled");
    assert!(stats.hits > 0, "the interleaving never hit: {stats:?}");
    assert!(stats.misses > 0, "the interleaving never missed: {stats:?}");
    assert!(stats.evictions > 0, "the budget never forced an eviction: {stats:?}");
    let budget = cached_engine.pack_cache_budget_bytes();
    for (p, s) in cached_engine.pack_cache_stats_per_pool().into_iter().enumerate() {
        let s = s.expect("per-pool cache is enabled");
        assert!(s.bytes <= budget, "pool {p}: resident {} bytes over budget {budget}", s.bytes);
    }
}

// ---------------------------------------------------------------------
// Stats sanity used by bench reporting
// ---------------------------------------------------------------------

#[test]
fn prop_geomean_between_min_and_max() {
    forall("geomean-bounds", |rng| {
        let xs: Vec<f64> = (0..rng.usize_below(20) + 1).map(|_| rng.f64() * 100.0 + 0.1).collect();
        let g = geomean(&xs);
        let mn = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = xs.iter().cloned().fold(0.0f64, f64::max);
        assert!(g >= mn - 1e-9 && g <= mx + 1e-9);
    });
}

// ---------------------------------------------------------------------
// Serving wire protocol
// ---------------------------------------------------------------------

/// Any representable GemmSpec must survive encode -> decode verbatim:
/// `to_wire_json` omits default-valued fields, so this also proves the
/// decoder's defaults match the encoder's.
#[test]
fn prop_wire_roundtrip() {
    use ftgemm::abft::injection::Injection;
    use ftgemm::abft::FtLevel;
    use ftgemm::coordinator::{FtPolicy, HostVerify, Priority};
    use ftgemm::serve::proto::{self, GemmSpec, WireRequest};
    use ftgemm::serve::wire::DEFAULT_MAX_DEPTH;

    forall("wire-roundtrip", |rng| {
        let mut spec = GemmSpec::new(
            rand_dims(rng, 1, 300),
            rand_dims(rng, 1, 300),
            rand_dims(rng, 1, 300),
        );
        spec.id = rng.usize_below(1 << 20) as u64;
        spec.policy = [FtPolicy::None, FtPolicy::Online, FtPolicy::Offline][rng.usize_below(3)];
        spec.seed = rng.usize_below(10_000) as u64;
        if rng.below(2) == 0 {
            spec.inject = rng.usize_below(4);
        } else {
            for _ in 0..rng.usize_below(4) {
                spec.injections.push(Injection {
                    row: rng.usize_below(spec.m),
                    col: rng.usize_below(spec.n),
                    step: rng.usize_below(64),
                    magnitude: rng.range_f32(-4096.0, 4096.0),
                });
            }
        }
        if rng.below(2) == 0 {
            spec.ft_level = Some(FtLevel::ALL[rng.usize_below(3)]);
        }
        if rng.below(2) == 0 {
            let modes = [HostVerify::Off, HostVerify::CleanOnly, HostVerify::Always];
            spec.host_verify = Some(modes[rng.usize_below(3)]);
        }
        if rng.below(2) == 0 {
            spec.threshold_rel = Some(rng.range_f32(1e-6, 1e-2));
        }
        if rng.below(2) == 0 {
            spec.threshold_abs = Some(rng.range_f32(1e-4, 10.0));
        }
        if rng.below(2) == 0 {
            spec.max_recomputes = Some(rng.usize_below(8));
        }
        spec.priority =
            [Priority::Low, Priority::Normal, Priority::High][rng.usize_below(3)];
        if rng.below(2) == 0 {
            // 0 decodes as "no deadline", so the wire value is always >= 1
            spec.deadline_ms = Some(1 + rng.usize_below(60_000) as u64);
        }

        let frame = spec.to_wire_json();
        let decoded = proto::decode(frame.as_bytes(), DEFAULT_MAX_DEPTH)
            .unwrap_or_else(|e| panic!("roundtrip decode of {frame}: {e:?}"));
        assert_eq!(decoded, WireRequest::Gemm(Box::new(spec)), "frame {frame}");
    });
}
