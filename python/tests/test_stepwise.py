"""The §3.1 step-wise ladder: every structural variant is the same GEMM."""

import numpy as np
import pytest

from compile.kernels import ref, stepwise
from compile.kernels.params import BUCKETS, TABLE1

RNG = np.random.default_rng(11)


def randm(m, n):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * 2.0


@pytest.mark.parametrize("variant", [v for v, _, real in stepwise.STEPWISE_LADDER if real])
def test_variant_matches_ref(variant):
    b = BUCKETS["small"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    fn = stepwise.STEPWISE_BUILDERS[variant](b.m, b.n, b.k, b.params)
    np.testing.assert_allclose(
        np.asarray(fn(a, x)),
        np.asarray(ref.gemm(a, x)),
        rtol=1e-4,
        atol=1e-4 * b.k,
    )


def test_ladder_is_complete():
    """Fig 9 has exactly seven steps; the ladder must enumerate them all
    (pallas-backed or model-only) for the gpusim figure harness."""
    assert len(stepwise.STEPWISE_LADDER) == 7
    names = [v for v, _, _ in stepwise.STEPWISE_LADDER]
    assert names[0] == "naive" and names[-1] == "prefetch_smem"


@pytest.mark.parametrize("variant", ["tbtile", "threadtile"])
def test_variants_agree_on_medium_preset(variant):
    p = TABLE1["medium"]
    m, n, k = 2 * p.m_tb, 3 * p.n_tb, 4 * p.k_tb
    a, x = randm(m, k), randm(k, n)
    fn = stepwise.STEPWISE_BUILDERS[variant](m, n, k, p)
    np.testing.assert_allclose(
        np.asarray(fn(a, x)), np.asarray(ref.gemm(a, x)), rtol=1e-4, atol=1e-4 * k
    )
