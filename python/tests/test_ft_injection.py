"""Fused FT-GEMM under error injection — the §5.3 protocol.

Errors are additive offsets on the accumulator at a chosen (row, col,
k-step). The online kernel must (a) detect each one, (b) correct it to
within f32 roundoff, (c) never fire on fault-free data, at every FT level.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.params import BUCKETS, MAX_INJ, VERIFY_EVERY, TABLE1
from compile.kernels.template import make_ft_gemm

RNG = np.random.default_rng(99)


def randm(m, n):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * 2.0


def inj_table(entries):
    t = np.zeros((MAX_INJ, 4), np.float32)
    for i, e in enumerate(entries):
        t[i] = e
    return t


def tol(k):
    return dict(rtol=1e-4, atol=2e-4 * k)


class TestSingleError:
    @pytest.mark.parametrize("level", ["thread", "warp", "tb"])
    def test_detected_and_corrected(self, level):
        b = BUCKETS["medium"]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        c, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level=level)(
            a, x, inj_table([[17, 93, 3, 250.0]])
        )
        assert float(np.asarray(err).sum()) == 1.0
        np.testing.assert_allclose(np.asarray(c), want, **tol(b.k))

    @given(
        row=st.integers(0, 63),
        col=st.integers(0, 63),
        step=st.integers(0, 3),
        mag=st.floats(10.0, 1e5),
        sign=st.sampled_from([-1.0, 1.0]),
        level=st.sampled_from(["thread", "warp", "tb"]),
    )
    @settings(max_examples=20, deadline=None)
    def test_any_position_any_magnitude(self, row, col, step, mag, sign, level):
        b = BUCKETS["small"]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        c, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level=level)(
            a, x, inj_table([[row, col, step, sign * mag]])
        )
        assert float(np.asarray(err).sum()) == 1.0
        # correction residue scales with the offset's own f32 roundoff:
        # dr is measured from sums carrying the injected magnitude, so the
        # corrected element keeps an O(eps * |mag|) remainder.
        np.testing.assert_allclose(
            np.asarray(c), want, rtol=1e-4, atol=2e-4 * b.k + 4e-6 * mag
        )


class TestMultipleErrors:
    @pytest.mark.parametrize("level", ["warp", "tb"])
    def test_errors_in_different_tiles_same_step(self, level):
        """SEU is per (sub-tile, interval) — distinct tiles may each take a
        hit in the same interval and all get corrected (the online scheme's
        advantage, §2.2: 'can handle multiple errors for the whole
        program')."""
        b = BUCKETS["medium"]  # 128^3, tiles 32x32 -> 4x4 grid
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        entries = [
            [0, 0, 0, 300.0],
            [40, 70, 2, -512.0],
            [100, 10, 5, 77.0],
            [127, 127, 9, 1e4],
        ]
        c, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level=level)(
            a, x, inj_table(entries)
        )
        assert float(np.asarray(err).sum()) == len(entries)
        np.testing.assert_allclose(np.asarray(c), want, **tol(b.k))

    def test_sequential_errors_same_tile_different_intervals(self):
        """One error per verification interval in the SAME tile — the online
        scheme corrects each before the next arrives."""
        b = BUCKETS["small"]  # k_tb=16 -> 4 steps, verify every 8 -> final+mid
        p = b.params
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        nsteps = b.k // p.k_tb
        # place one error in each verification interval
        entries = [[5, 5, s, 100.0 + 10 * s] for s in range(0, nsteps, VERIFY_EVERY)]
        c, _, _, err = make_ft_gemm(b.m, b.n, b.k, p, level="tb")(
            a, x, inj_table(entries)
        )
        assert float(np.asarray(err).sum()) == len(entries)
        np.testing.assert_allclose(np.asarray(c), want, **tol(b.k))

    def test_thread_level_corrects_two_errors_same_tile_same_step(self):
        """Finer granularity = more SEU domains: two errors in the same
        32x32 tile but different thread micro-tiles are both corrected at
        thread level (they would alias at tb level)."""
        b = BUCKETS["medium"]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        # same tile (0,0): micro-tiles are 4x4 -> (0..3,0..3) and (8..11,..)
        entries = [[1, 1, 0, 200.0], [9, 9, 0, -150.0]]
        c, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level="thread")(
            a, x, inj_table(entries)
        )
        assert float(np.asarray(err).sum()) == 2.0
        np.testing.assert_allclose(np.asarray(c), want, **tol(b.k))


class TestDetectOnly:
    def test_detects_but_leaves_fault(self):
        b = BUCKETS["medium"]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        want = np.asarray(ref.gemm(a, x))
        c, _, _, err = make_ft_gemm(
            b.m, b.n, b.k, b.params, level="tb", correct=False
        )(a, x, inj_table([[3, 4, 0, 123.0]]))
        assert float(np.asarray(err).sum()) >= 1.0
        diff = np.abs(np.asarray(c) - want)
        assert diff.max() == pytest.approx(123.0, rel=1e-3)
        # ... and exactly one element is corrupted
        assert (diff > 1.0).sum() == 1


class TestNoFalsePositives:
    @pytest.mark.parametrize("cls", ["small", "medium", "large", "tall", "huge"])
    def test_all_buckets_clean(self, cls):
        """Threshold calibration: zero detections on fault-free data at
        every bucket size (the huge bucket stresses f32 drift the most)."""
        b = BUCKETS[cls]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        _, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb")(
            a, x, np.zeros((MAX_INJ, 4), np.float32)
        )
        assert float(np.asarray(err).sum()) == 0.0, cls

    def test_tiny_offsets_below_threshold_are_ignored(self):
        """An offset within roundoff must not trigger (avoids correction
        storms on benign drift)."""
        b = BUCKETS["small"]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        _, _, _, err = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb")(
            a, x, inj_table([[2, 2, 0, 1e-5]])
        )
        assert float(np.asarray(err).sum()) == 0.0
