"""Ding'11 non-fused baseline stages vs oracle — including the chained
pipeline exactly as the rust coordinator drives it (encode once, then
step/inject/verify per panel)."""

import numpy as np
import pytest

from compile.kernels import nonfused, ref
from compile.kernels.params import BUCKETS
from compile.model import DING_KS

RNG = np.random.default_rng(3)


def randm(m, n):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * 2.0


@pytest.mark.parametrize("cls", list(DING_KS))
def test_pipeline_matches_oracle(cls):
    b = BUCKETS[cls]
    ks = DING_KS[cls]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    encode = nonfused.make_ding_encode(b.m, b.n, b.k)
    step = nonfused.make_ding_step(b.m, b.n, ks)
    ac, br = encode(a, x)
    cf = np.zeros((b.m + 1, b.n + 1), np.float32)
    for s in range(0, b.k, ks):
        cf = np.asarray(step(cf, np.asarray(ac)[:, s : s + ks], np.asarray(br)[s : s + ks, :])[0])
    want = np.asarray(ref.full_checksum_product(a, x))
    np.testing.assert_allclose(cf, want, rtol=1e-4, atol=2e-4 * b.k)


def test_verify_corrects_injected_panel_error():
    b = BUCKETS["medium"]
    ks = DING_KS["medium"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    encode = nonfused.make_ding_encode(b.m, b.n, b.k)
    step = nonfused.make_ding_step(b.m, b.n, ks)
    verify = nonfused.make_ding_verify(b.m, b.n)
    ac, br = np.asarray(encode(a, x)[0]), np.asarray(encode(a, x)[1])
    cf = np.zeros((b.m + 1, b.n + 1), np.float32)
    total_corrected = 0.0
    for idx, s in enumerate(range(0, b.k, ks)):
        cf = np.asarray(step(cf, ac[:, s : s + ks], br[s : s + ks, :])[0]).copy()
        if idx == 1:  # inject one SEU into this panel's accumulation
            cf[37, 11] += 444.0
        cf_fixed, nerr = verify(cf)
        cf = np.asarray(cf_fixed)
        total_corrected += float(nerr)
    assert total_corrected == 1.0
    want = np.asarray(ref.full_checksum_product(a, x))
    np.testing.assert_allclose(cf, want, rtol=1e-4, atol=2e-4 * b.k)


def test_verify_is_identity_on_clean_cf():
    b = BUCKETS["medium"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    cf = np.asarray(ref.full_checksum_product(a, x))
    verify = nonfused.make_ding_verify(b.m, b.n)
    fixed, nerr = verify(cf)
    assert float(nerr) == 0.0
    np.testing.assert_array_equal(np.asarray(fixed), cf)
