"""Verify-interval ablation semantics (DESIGN.md §Perf, ablation bench).

The verification period trades performance against the SEU window: with
``verify_every=1`` the kernel verifies after *every* k-step, so it can
correct one error per tile per STEP — strictly more than the default
period-8 kernel, which aliases two errors inside one interval.
"""

import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.params import BUCKETS, MAX_INJ
from compile.kernels.template import make_ft_gemm

RNG = np.random.default_rng(21)


def randm(m, n):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * 2.0


def inj_table(entries):
    t = np.zeros((MAX_INJ, 4), np.float32)
    for i, e in enumerate(entries):
        t[i] = e
    return t


def test_ve1_corrects_two_errors_same_tile_adjacent_steps():
    b = BUCKETS["medium"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    want = np.asarray(ref.gemm(a, x))
    # two SEUs in the SAME 32x32 tile at consecutive k-steps: one
    # verification interval at ve=8 (aliased), two intervals at ve=1
    entries = [[3, 4, 0, 500.0], [10, 20, 1, -800.0]]
    ft1 = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb", verify_every=1)
    c, _, _, err = ft1(a, x, inj_table(entries))
    assert float(np.asarray(err).sum()) == 2.0
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-4, atol=2e-4 * b.k)


def test_ve8_defers_correction_of_aliased_pair_to_next_interval():
    """Two same-tile errors inside ONE verification window alias at the
    window's check (only the larger is corrected there) — but because the
    carried checksums derive from the INPUTS, the residual corruption is
    re-detected and corrected at the NEXT interval. Deferred, not lost."""
    b = BUCKETS["medium"]  # 16 k-steps, verify at 7 and 15
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    want = np.asarray(ref.gemm(a, x))
    entries = [[3, 4, 0, 500.0], [10, 20, 1, -800.0]]  # both in interval 0
    ft8 = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb", verify_every=8)
    c, _, _, err = ft8(a, x, inj_table(entries))
    assert float(np.asarray(err).sum()) == 2.0
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-4, atol=2e-4 * b.k)


def test_ve8_truly_aliases_in_the_final_interval():
    """If the second aliased error lands in the LAST interval there is no
    later verification to catch the leftover — the genuine SEU-violation
    failure mode; documents why the campaign planner allocates one error
    per (tile, interval) domain."""
    b = BUCKETS["medium"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    want = np.asarray(ref.gemm(a, x))
    entries = [[3, 4, 14, 500.0], [10, 20, 15, -800.0]]  # both in interval 1 (last)
    ft8 = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb", verify_every=8)
    c, _, _, _ = ft8(a, x, inj_table(entries))
    assert np.abs(np.asarray(c) - want).max() > 1.0


@pytest.mark.parametrize("ve", [1, 4, 16])
def test_all_intervals_clean_on_fault_free(ve):
    b = BUCKETS["small"]
    a, x = randm(b.m, b.k), randm(b.k, b.n)
    ft = make_ft_gemm(b.m, b.n, b.k, b.params, level="tb", verify_every=ve)
    c, _, _, err = ft(a, x, np.zeros((MAX_INJ, 4), np.float32))
    assert float(np.asarray(err).sum()) == 0.0
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(ref.gemm(a, x)), rtol=1e-4, atol=1e-4 * b.k
    )
