"""Oracle self-consistency: the checksum algebra of paper §2.2 must hold on
the pure-jnp reference before it can judge any kernel."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

RNG = np.random.default_rng(1234)


def randm(m, n, scale=1.0):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * scale


dims = st.sampled_from([1, 2, 3, 4, 8, 16, 31, 64])


class TestEncodings:
    @given(m=dims, k=dims)
    @settings(max_examples=25, deadline=None)
    def test_encode_a_appends_column_sums(self, m, k):
        a = randm(m, k)
        ac = np.asarray(ref.encode_a(a))
        assert ac.shape == (m + 1, k)
        np.testing.assert_allclose(ac[-1], a.sum(axis=0), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(ac[:-1], a)

    @given(k=dims, n=dims)
    @settings(max_examples=25, deadline=None)
    def test_encode_b_appends_row_sums(self, k, n):
        b = randm(k, n)
        br = np.asarray(ref.encode_b(b))
        assert br.shape == (k, n + 1)
        np.testing.assert_allclose(br[:, -1], b.sum(axis=1), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(br[:, :-1], b)

    @given(m=dims, k=dims, n=dims)
    @settings(max_examples=20, deadline=None)
    def test_checksum_product_invariant(self, m, k, n):
        """eq. 3: C^f carries C, Ce, and e^T C simultaneously."""
        a, b = randm(m, k), randm(k, n)
        cf = np.asarray(ref.full_checksum_product(a, b))
        c = np.asarray(ref.gemm(a, b))
        tol = dict(rtol=1e-4, atol=1e-4 * k)
        np.testing.assert_allclose(cf[:-1, :-1], c, **tol)
        np.testing.assert_allclose(cf[:-1, -1], c.sum(axis=1), **tol)
        np.testing.assert_allclose(cf[-1, :-1], c.sum(axis=0), **tol)


class TestSubtileChecksums:
    @pytest.mark.parametrize("sm,sn", [(2, 2), (4, 8), (8, 4), (16, 16)])
    def test_subtile_sums_partition_full_sums(self, sm, sn):
        c = randm(32, 32)
        rs = np.asarray(ref.subtile_row_checksums(c, sm, sn))
        cs = np.asarray(ref.subtile_col_checksums(c, sm, sn))
        assert rs.shape == (32 // sm, sm, 32 // sn)
        assert cs.shape == (32 // sm, 32 // sn, sn)
        # summing sub-tile checksums over their band recovers global sums
        np.testing.assert_allclose(
            rs.sum(axis=2).reshape(-1), c.sum(axis=1), rtol=1e-5, atol=1e-4
        )
        np.testing.assert_allclose(
            cs.sum(axis=0).reshape(-1), c.sum(axis=0), rtol=1e-5, atol=1e-4
        )

    def test_tb_granularity_equals_whole_matrix(self):
        c = randm(16, 16)
        rs = np.asarray(ref.subtile_row_checksums(c, 16, 16))
        np.testing.assert_allclose(rs[0, :, 0], c.sum(axis=1), rtol=1e-5, atol=1e-4)


class TestDetectCorrect:
    def test_single_error_located_and_corrected(self):
        a, b = randm(24, 16), randm(16, 20)
        c = np.asarray(ref.gemm(a, b))
        cr, cc = c.sum(axis=1), c.sum(axis=0)
        bad = ref.apply_injections(c, [(5, 7, 42.0)])
        fixed, n = ref.detect_and_correct(bad, cr, cc)
        assert n == 1
        np.testing.assert_allclose(np.asarray(fixed), c, rtol=1e-4, atol=1e-3)

    def test_no_false_positive_on_clean_result(self):
        a, b = randm(32, 64), randm(64, 16)
        c = np.asarray(ref.gemm(a, b))
        fixed, n = ref.detect_and_correct(c, c.sum(axis=1), c.sum(axis=0))
        assert n == 0
        np.testing.assert_array_equal(np.asarray(fixed), c)

    @given(
        r=st.integers(0, 23),
        col=st.integers(0, 19),
        mag=st.floats(5.0, 1e4),
        sign=st.sampled_from([-1.0, 1.0]),
    )
    @settings(max_examples=30, deadline=None)
    def test_correction_is_exact_up_to_roundoff(self, r, col, mag, sign):
        a, b = randm(24, 16), randm(16, 20)
        c = np.asarray(ref.gemm(a, b))
        bad = ref.apply_injections(c, [(r, col, sign * mag)])
        fixed, n = ref.detect_and_correct(bad, c.sum(axis=1), c.sum(axis=0))
        assert n == 1
        np.testing.assert_allclose(np.asarray(fixed), c, rtol=1e-4, atol=1e-2)


class TestDing:
    @pytest.mark.parametrize("ks", [4, 8, 16])
    def test_outer_product_equals_full_product(self, ks):
        a, b = randm(16, 32), randm(32, 8)
        cf = np.asarray(ref.ding_outer_product(a, b, ks))
        want = np.asarray(ref.full_checksum_product(a, b))
        np.testing.assert_allclose(cf, want, rtol=1e-4, atol=1e-3)

    def test_verify_accepts_clean_rejects_faulty(self):
        a, b = randm(16, 32), randm(32, 8)
        cf = ref.ding_outer_product(a, b, 8)
        _, _, ok = ref.ding_verify(cf)
        assert bool(ok)
        bad = np.asarray(cf).copy()
        bad[3, 4] += 77.0
        _, _, ok = ref.ding_verify(jnp.asarray(bad))
        assert not bool(ok)
