"""Codegen template vs oracle: hypothesis sweeps shapes x Table-1 params.

This is the L1 correctness core — every generated kernel must compute the
same C = A·B as the pure-jnp reference, for every parameter preset and a
range of (possibly irregular) divisible shapes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.params import BUCKETS, MAX_INJ, TABLE1, KernelParams, select_class
from compile.kernels.template import make_ft_gemm, make_gemm, mxu_flops_ratio, vmem_bytes

RNG = np.random.default_rng(7)


def randm(m, n, scale=1.0):
    return (RNG.random((m, n), dtype=np.float32) - 0.5) * scale


def no_inj():
    return np.zeros((MAX_INJ, 4), np.float32)


def assert_matches_ref(c, a, b, k):
    want = np.asarray(ref.gemm(a, b))
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-4, atol=1e-4 * k)


class TestPlainTemplate:
    @pytest.mark.parametrize("cls", list(TABLE1))
    def test_every_preset_on_its_bucket(self, cls):
        b = BUCKETS[cls]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        c = make_gemm(b.m, b.n, b.k, b.params)(a, x)[0]
        assert_matches_ref(c, a, x, b.k)

    @given(
        mi=st.integers(1, 4),
        ni=st.integers(1, 4),
        ki=st.integers(1, 6),
        cls=st.sampled_from(["small", "medium"]),
    )
    @settings(max_examples=12, deadline=None)
    def test_irregular_divisible_shapes(self, mi, ni, ki, cls):
        """Sweep non-square shapes that are exact multiples of the tile."""
        p = TABLE1[cls]
        m, n, k = mi * p.m_tb, ni * p.n_tb, ki * p.k_tb
        a, x = randm(m, k), randm(k, n)
        c = make_gemm(m, n, k, p)(a, x)[0]
        assert_matches_ref(c, a, x, k)

    def test_rejects_non_divisible_shape(self):
        with pytest.raises(ValueError):
            make_gemm(100, 64, 64, TABLE1["small"])

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            KernelParams(16, 16, 16, 5, 16, 2, 2).validate()
        with pytest.raises(ValueError):
            KernelParams(16, 16, 16, 32, 16, 2, 2).validate()  # warp > block


class TestFtTemplateFaultFree:
    @pytest.mark.parametrize("level", ["thread", "warp", "tb"])
    @pytest.mark.parametrize("cls", ["small", "medium"])
    def test_matches_plain_gemm(self, level, cls):
        b = BUCKETS[cls]
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        c, cr, cc, err = make_ft_gemm(b.m, b.n, b.k, b.params, level=level)(
            a, x, no_inj()
        )
        assert float(np.asarray(err).sum()) == 0.0, "false positive detection"
        assert_matches_ref(c, a, x, b.k)

    @pytest.mark.parametrize("level", ["thread", "warp", "tb"])
    def test_carried_checksums_match_oracle(self, level):
        """The CR/CC outputs must equal the oracle's sub-tile checksums of
        the true product — they are what the rust host re-verifies."""
        b = BUCKETS["small"]
        p = b.params
        sm, sn = p.sub_tile(level)
        a, x = randm(b.m, b.k), randm(b.k, b.n)
        c, cr, cc, _ = make_ft_gemm(b.m, b.n, b.k, p, level=level)(a, x, no_inj())
        want = np.asarray(ref.gemm(a, x))
        gm, gn = b.m // p.m_tb, b.n // p.n_tb
        cr = np.asarray(cr)
        cc = np.asarray(cc)
        for i in range(gm):
            for j in range(gn):
                tile = want[
                    i * p.m_tb : (i + 1) * p.m_tb, j * p.n_tb : (j + 1) * p.n_tb
                ]
                np.testing.assert_allclose(
                    cr[i, j],
                    np.asarray(ref.subtile_row_checksums(tile, sm, sn)),
                    rtol=1e-3,
                    atol=1e-2,
                )
                np.testing.assert_allclose(
                    cc[i, j],
                    np.asarray(ref.subtile_col_checksums(tile, sm, sn)),
                    rtol=1e-3,
                    atol=1e-2,
                )

    @given(
        mi=st.integers(1, 3),
        ni=st.integers(1, 3),
        ki=st.integers(1, 4),
        level=st.sampled_from(["thread", "warp", "tb"]),
    )
    @settings(max_examples=10, deadline=None)
    def test_shape_sweep_fault_free(self, mi, ni, ki, level):
        p = TABLE1["small"]
        m, n, k = mi * p.m_tb, ni * p.n_tb, ki * p.k_tb
        a, x = randm(m, k), randm(k, n)
        c, _, _, err = make_ft_gemm(m, n, k, p, level=level)(a, x, no_inj())
        assert float(np.asarray(err).sum()) == 0.0
        assert_matches_ref(c, a, x, k)


class TestStructuralEstimates:
    def test_vmem_fits_typical_budget(self):
        """Every Table-1 preset must fit a 16 MiB VMEM comfortably (the
        point of tiling); FT adds only a small increment."""
        for cls, p in TABLE1.items():
            base = vmem_bytes(p)
            ft = vmem_bytes(p, level="tb")
            assert ft < 16 * 2**20, cls
            assert base < ft < 1.5 * base + 4096, cls

    def test_mxu_ratio_ordering_matches_paper(self):
        """§4.2.2: checksum compute overhead shrinks as granularity grows —
        thread-level worst, threadblock-level best."""
        for cls, p in TABLE1.items():
            r_t = mxu_flops_ratio(p, "thread")
            r_w = mxu_flops_ratio(p, "warp")
            r_b = mxu_flops_ratio(p, "tb")
            assert r_t < r_w < r_b <= 1.0, cls

    def test_thread_level_overhead_formula(self):
        """Paper: thread-level ABFT adds (4 n_t)/(2 n_t^2) = 2/n_t compute
        for square micro-tiles — our ratio must agree to first order."""
        p = TABLE1["huge"]  # m_t = n_t = 8
        r = mxu_flops_ratio(p, "thread")
        expect = 1.0 / (1.0 + 2.0 / p.n_t)
        assert abs(r - expect) / expect < 0.15


class TestShapeClassSelection:
    @pytest.mark.parametrize(
        "m,n,k,cls",
        [
            (64, 64, 64, "small"),
            (128, 128, 512, "small"),
            (160, 160, 256, "medium"),
            (384, 384, 256, "large"),
            (1024, 1024, 1024, "huge"),
            (64, 1024, 256, "tall"),
            (2048, 128, 1024, "tall"),
        ],
    )
    def test_paper_heuristic(self, m, n, k, cls):
        assert select_class(m, n, k) == cls
