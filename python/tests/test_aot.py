"""AOT pipeline integrity: the registry is complete, every spec lowers to
HLO text the 0.5.1-era parser accepts (no 64-bit-id protos — we check the
text path is used), and the manifest round-trips shapes faithfully."""

import json
import os

import jax
import pytest

from compile import aot
from compile.model import REGISTRY
from compile.kernels.params import BUCKETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestRegistry:
    def test_expected_variant_families_present(self):
        names = set(REGISTRY)
        for b in BUCKETS:
            assert f"gemm_{b}" in names
            assert f"ftgemm_tb_{b}" in names
        for b in ("medium", "huge"):
            assert f"ftgemm_warp_{b}" in names
            assert f"ftgemm_thread_{b}" in names
            assert f"ftdetect_{b}" in names
        assert "ding_step_huge" in names
        assert "stepwise_naive_small" in names

    def test_specs_are_internally_consistent(self):
        for spec in REGISTRY.values():
            outs = jax.eval_shape(spec.fn, *spec.args)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            assert len(outs) == len(spec.outputs), spec.name
            assert spec.meta.get("kind"), spec.name

    def test_ft_meta_records_granularity(self):
        spec = REGISTRY["ftgemm_warp_medium"]
        p = spec.meta["params"]
        assert spec.meta["sub_m"] == p["m_w"]
        assert spec.meta["sub_n"] == p["n_w"]


class TestLowering:
    @pytest.mark.parametrize("name", ["gemm_small", "ftgemm_tb_small", "ding_verify_medium"])
    def test_lowers_to_parseable_hlo_text(self, name):
        hlo = aot.lower_spec(REGISTRY[name])
        assert hlo.startswith("HloModule"), "must be HLO text, not proto bytes"
        assert "ROOT" in hlo
        # return_tuple=True => root is a tuple (rust side calls to_tuple)
        assert "tuple(" in hlo or "(f32[" in hlo


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_registry_entry_present(self, manifest):
        have = {e["name"] for e in manifest["artifacts"]}
        assert have == set(REGISTRY)

    def test_files_exist_and_match_spec_shapes(self, manifest):
        for e in manifest["artifacts"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["name"]
            spec = REGISTRY[e["name"]]
            assert [list(a.shape) for a in spec.args] == [
                i["shape"] for i in e["inputs"]
            ]
            assert [o["role"] for o in e["outputs"]] == list(spec.outputs)

    def test_hlo_files_are_text(self, manifest):
        for e in manifest["artifacts"][:5]:
            with open(os.path.join(ART, e["file"])) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), e["name"]
