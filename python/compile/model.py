"""L2 variant registry: every AOT artifact the rust runtime can load.

Each `ArtifactSpec` pairs a jax-traceable function (built by the L1 codegen
in kernels/) with its concrete example-argument shapes and the metadata the
rust side needs (output roles, bucket dims, tile params, FT level...).
`aot.py` lowers every spec to HLO text; `artifacts/manifest.json` is the
single source of truth the rust runtime reads at startup.

Naming convention (mirrored in rust/src/runtime/artifact.rs):

    gemm_<bucket>                plain codegen GEMM
    ftgemm_<level>_<bucket>      fused online FT-GEMM (level: tb|warp|thread)
    ftdetect_<bucket>            fused detect-only (offline ABFT, §5.5)
    ding_{encode,step,verify}_<bucket>   non-fused Ding'11 baseline stages
    stepwise_<variant>_<bucket>  §3.1 ladder variants (numerics witnesses)
"""

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import nonfused, stepwise, template
from .kernels.params import BUCKETS, MAX_INJ, VERIFY_EVERY, Bucket


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


@dataclass
class ArtifactSpec:
    name: str
    fn: Callable
    args: Sequence[jax.ShapeDtypeStruct]
    outputs: Sequence[str]  # role names, in return order
    meta: dict = field(default_factory=dict)


def _gemm_spec(b: Bucket) -> ArtifactSpec:
    return ArtifactSpec(
        name=f"gemm_{b.name}",
        fn=template.make_gemm(b.m, b.n, b.k, b.params),
        args=[f32(b.m, b.k), f32(b.k, b.n)],
        outputs=["c"],
        meta={
            "kind": "gemm",
            "bucket": b.name,
            "m": b.m,
            "n": b.n,
            "k": b.k,
            "params": b.params.to_dict(),
        },
    )


def _ft_spec(b: Bucket, level: str, correct: bool = True) -> ArtifactSpec:
    sm, sn = b.params.sub_tile(level)
    name = f"ftgemm_{level}_{b.name}" if correct else f"ftdetect_{b.name}"
    return ArtifactSpec(
        name=name,
        fn=template.make_ft_gemm(
            b.m, b.n, b.k, b.params, level=level, correct=correct
        ),
        args=[f32(b.m, b.k), f32(b.k, b.n), f32(MAX_INJ, 4)],
        outputs=["c", "cr", "cc", "errcount"],
        meta={
            "kind": "ftgemm" if correct else "ftdetect",
            "bucket": b.name,
            "m": b.m,
            "n": b.n,
            "k": b.k,
            "params": b.params.to_dict(),
            "ft_level": level,
            "sub_m": sm,
            "sub_n": sn,
            "max_inj": MAX_INJ,
            "verify_every": VERIFY_EVERY,
            "correct": correct,
        },
    )


def _ding_specs(b: Bucket, ks: int) -> list[ArtifactSpec]:
    m, n, k = b.m, b.n, b.k
    common = {"bucket": b.name, "m": m, "n": n, "k": k, "ks": ks}
    return [
        ArtifactSpec(
            name=f"ding_encode_{b.name}",
            fn=nonfused.make_ding_encode(m, n, k),
            args=[f32(m, k), f32(k, n)],
            outputs=["ac", "br"],
            meta={"kind": "ding_encode", **common},
        ),
        ArtifactSpec(
            name=f"ding_step_{b.name}",
            fn=nonfused.make_ding_step(m, n, ks),
            args=[f32(m + 1, n + 1), f32(m + 1, ks), f32(ks, n + 1)],
            outputs=["cf"],
            meta={"kind": "ding_step", **common},
        ),
        ArtifactSpec(
            name=f"ding_verify_{b.name}",
            fn=nonfused.make_ding_verify(m, n),
            args=[f32(m + 1, n + 1)],
            outputs=["cf", "errcount"],
            meta={"kind": "ding_verify", **common},
        ),
    ]


def _stepwise_specs(b: Bucket) -> list[ArtifactSpec]:
    out = []
    for variant, desc, has_builder in stepwise.STEPWISE_LADDER:
        if not has_builder:
            continue
        builder = stepwise.STEPWISE_BUILDERS[variant]
        fn = builder(b.m, b.n, b.k, b.params)
        out.append(
            ArtifactSpec(
                name=f"stepwise_{variant}_{b.name}",
                fn=lambda a, x, _fn=fn: (_fn(a, x),),
                args=[f32(b.m, b.k), f32(b.k, b.n)],
                outputs=["c"],
                meta={
                    "kind": "stepwise",
                    "variant": variant,
                    "desc": desc,
                    "bucket": b.name,
                    "m": b.m,
                    "n": b.n,
                    "k": b.k,
                    "params": b.params.to_dict(),
                },
            )
        )
    return out


# K_s panel width for the non-fused baseline, per bucket (the paper's Fig 16
# uses K_s = 256; smaller buckets scale it down so there are >= 2 panels).
DING_KS = {"medium": 64, "large": 128, "huge": 256}


def _ablation_specs(b: Bucket) -> list[ArtifactSpec]:
    """Verify-interval ablation (DESIGN.md §Perf): the same tb-level fused
    kernel lowered with different verification periods. The bucket string
    is suffixed so the router never picks these; the perf harness and the
    ablation bench address them by name."""
    out = []
    for ve in (1, 4, 16):
        spec = ArtifactSpec(
            name=f"ftgemm_tb_{b.name}_ve{ve}",
            fn=template.make_ft_gemm(
                b.m, b.n, b.k, b.params, level="tb", verify_every=ve
            ),
            args=[f32(b.m, b.k), f32(b.k, b.n), f32(MAX_INJ, 4)],
            outputs=["c", "cr", "cc", "errcount"],
            meta={
                "kind": "ftgemm",
                "bucket": f"{b.name}_ve{ve}",
                "m": b.m,
                "n": b.n,
                "k": b.k,
                "params": b.params.to_dict(),
                "ft_level": "tb",
                "sub_m": b.params.m_tb,
                "sub_n": b.params.n_tb,
                "max_inj": MAX_INJ,
                "verify_every": ve,
                "correct": True,
            },
        )
        out.append(spec)
    return out


def build_registry() -> dict[str, ArtifactSpec]:
    specs: list[ArtifactSpec] = []
    for b in BUCKETS.values():
        specs.append(_gemm_spec(b))
        specs.append(_ft_spec(b, "tb"))
    # all three FT levels + detect-only where the scheme comparison runs
    for name in ("medium", "huge"):
        b = BUCKETS[name]
        specs.append(_ft_spec(b, "warp"))
        specs.append(_ft_spec(b, "thread"))
        specs.append(_ft_spec(b, "tb", correct=False))
    for name, ks in DING_KS.items():
        specs.extend(_ding_specs(BUCKETS[name], ks))
    specs.extend(_stepwise_specs(BUCKETS["small"]))
    specs.extend(_ablation_specs(BUCKETS["medium"]))
    reg = {s.name: s for s in specs}
    assert len(reg) == len(specs), "duplicate artifact names"
    return reg


REGISTRY = build_registry()
