"""Step-wise SGEMM optimization variants (paper §3.1 / Fig 9).

The paper walks seven steps from a naive CUDA kernel to one that beats
cuBLAS. Steps differ in *where data is reused*, which on TPU maps to block
shapes and scheduling rather than explicit shared-memory code; the variants
below reproduce the three *structurally distinct* stages as real Pallas
kernels (all numerically identical to C = A·B — pytest asserts that), and
the remaining stages (vectorized load/store, the two prefetch pipelines)
are pure scheduling concerns, modeled analytically in
rust/src/gpusim/stepwise.rs which regenerates the Fig 9 GFLOPS series.

    v0 naive        : no operand reuse — each program streams a full K-row /
                      K-column per tiny output tile (the O(n^3) global
                      traffic of §3.1.1).
    v1 tb-tiling    : threadblock tile + k-loop accumulation (shared-memory
                      reuse of §3.1.2).
    v2 thread-tiling: micro-tile (m_t, n_t) structure inside the tile
                      (register reuse of §3.1.3); expressed as a blocked
                      einsum so the register-block structure is explicit in
                      the lowered HLO.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .params import KernelParams


def make_naive(m: int, n: int, k: int, tile: int = 8):
    """§3.1.1: each program owns a tile x tile output block and reads the
    full K extent of A and B from "global memory" (no k-blocking, no reuse
    across programs)."""
    if m % tile or n % tile:
        raise ValueError("naive tile must divide m, n")

    def kernel(a_ref, b_ref, c_ref):
        c_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(m // tile, n // tile),
        in_specs=[
            pl.BlockSpec((tile, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )


def make_tb_tiled(m: int, n: int, k: int, p: KernelParams):
    """§3.1.2: threadblock tiling — k becomes a grid dimension, operand
    tiles are VMEM-resident and reused across the tile's output elements."""

    def kernel(a_ref, b_ref, c_ref):
        @pl.when(pl.program_id(2) == 0)
        def _():
            c_ref[...] = jnp.zeros(c_ref.shape, jnp.float32)

        c_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(m // p.m_tb, n // p.n_tb, k // p.k_tb),
        in_specs=[
            pl.BlockSpec((p.m_tb, p.k_tb), lambda i, j, s: (i, s)),
            pl.BlockSpec((p.k_tb, p.n_tb), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((p.m_tb, p.n_tb), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )


def make_thread_tiled(m: int, n: int, k: int, p: KernelParams):
    """§3.1.3: adds the (m_t, n_t) micro-tile structure — the blocked einsum
    makes the register-block loop nest explicit in the lowered HLO (each
    (m_t, n_t) block is one register accumulation in the CUDA original)."""
    S_m, S_n = p.m_tb // p.m_t, p.n_tb // p.n_t

    def kernel(a_ref, b_ref, c_ref):
        @pl.when(pl.program_id(2) == 0)
        def _():
            c_ref[...] = jnp.zeros(c_ref.shape, jnp.float32)

        a4 = a_ref[...].reshape(S_m, p.m_t, p.k_tb)
        b4 = b_ref[...].reshape(p.k_tb, S_n, p.n_t)
        # (S_m, m_t, S_n, n_t): one einsum term per micro-tile register block
        blocks = jnp.einsum("aik,kbj->aibj", a4, b4)
        c_ref[...] += blocks.reshape(p.m_tb, p.n_tb)

    return pl.pallas_call(
        kernel,
        grid=(m // p.m_tb, n // p.n_tb, k // p.k_tb),
        in_specs=[
            pl.BlockSpec((p.m_tb, p.k_tb), lambda i, j, s: (i, s)),
            pl.BlockSpec((p.k_tb, p.n_tb), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((p.m_tb, p.n_tb), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )


STEPWISE_BUILDERS = {
    "naive": lambda m, n, k, p: make_naive(m, n, k),
    "tbtile": make_tb_tiled,
    "threadtile": make_thread_tiled,
}

# The full seven-step ladder of Fig 9; entries without a pallas builder are
# scheduling-only refinements whose cost model lives in gpusim::stepwise.
STEPWISE_LADDER = [
    ("naive", "naive baseline", True),
    ("tbtile", "threadblock-level tiling", True),
    ("threadtile", "thread-level tiling", True),
    ("warptile", "warp-level tiling", False),
    ("vectorized", "128-bit vectorized load/store", False),
    ("prefetch_reg", "prefetch shared->register", False),
    ("prefetch_smem", "prefetch global->shared", False),
]
