"""Non-fused ABFT GEMM — the Ding et al. 2011 baseline (paper §2.2, Figs
12-16 "non-fused FT-SGEMM").

Ding's scheme runs the outer-product GEMM as a sequence of separate kernel
launches over K_s-wide panels of the *encoded* operands, verifying the
checksum relationship between launches. Nothing is fused: the encoded C^f
matrix is re-read and re-written from global memory at every step, and the
encodings themselves are standalone kernels. We reproduce that structure
faithfully as three separate AOT artifacts that the rust coordinator chains
with one PJRT execution per launch — so the "extra memory passes" the paper
attributes to the baseline are real executions here too:

    ding_encode : (A, B)            -> (A^c, B^r)          one launch
    ding_step   : (C^f, A^c_s, B^r_s) -> C^f + A^c_s B^r_s one launch PER k-panel
    ding_verify : (C^f,)            -> (C^f corrected, nerr) one launch per panel

Injection for this baseline happens host-side (the rust fault driver adds
the offset to C^f between step and verify — same additive-SEU protocol).
"""

import jax.numpy as jnp

from . import ref


def make_ding_encode(m: int, n: int, k: int):
    """Encode both operands: A -> [A; e^T A], B -> [B, B e]."""

    def encode(a, b):
        return ref.encode_a(a), ref.encode_b(b)

    return encode


def make_ding_step(m: int, n: int, ks: int):
    """One outer-product panel update: C^f += A^c[:, s:s+ks] B^r[s:s+ks, :].
    The panel slicing is done host-side (rust) so the artifact shape is
    fixed at (m+1, ks) x (ks, n+1)."""

    def step(cf, ac_panel, br_panel):
        return (cf + jnp.dot(ac_panel, br_panel, preferred_element_type=jnp.float32),)

    return step


def make_ding_verify(m: int, n: int, rel: float = 1e-4, abs_: float = 1e-3):
    """Verify + single-error-correct a full C^f against its own carried
    checksums (last row = e^T C, last column = C e). Returns the corrected
    C^f and the number of corrections (0.0 or 1.0) — one SEU per
    verification interval, as in the original scheme."""

    def verify(cf):
        c = cf[:-1, :-1]
        crow = cf[:-1, -1]  # carried C e
        ccol = cf[-1, :-1]  # carried e^T C
        dr = jnp.sum(c, axis=1) - crow
        dc = jnp.sum(c, axis=0) - ccol
        tr = rel * (jnp.sum(jnp.abs(c), axis=1) + jnp.abs(crow)) + abs_
        tc = rel * (jnp.sum(jnp.abs(c), axis=0) + jnp.abs(ccol)) + abs_
        det = (jnp.abs(dr) > tr).any() & (jnp.abs(dc) > tc).any()
        r = jnp.argmax(jnp.abs(dr))
        col = jnp.argmax(jnp.abs(dc))
        mag = jnp.where(det, dr[r], 0.0)
        fix = (
            mag
            * (jnp.arange(m + 1) == r)[:, None].astype(jnp.float32)
            * (jnp.arange(n + 1) == col)[None, :].astype(jnp.float32)
        )
        return cf - fix, jnp.where(det, 1.0, 0.0)

    return verify
