"""Pure-jnp oracles for the GEMM + ABFT kernels.

Everything in this file is the *specification*: plain jax.numpy with no
pallas, no tiling, no cleverness. pytest checks every pallas kernel and
every lowered artifact against these functions.

Checksum algebra (paper §2.2, eq. 1-3):

    A^c = [A; e^T A]        (column-checksum encoding: extra row)
    B^r = [B, B e]          (row-checksum encoding: extra column)
    C^f = A^c B^r = [[C, Ce], [e^T C, *]]

so `Ce` (row sums of C) and `e^T C` (column sums of C) are carried along by
the multiplication itself; a mismatch between recomputed sums of C and the
carried checksums locates an error: the faulty row from the Ce residual,
the faulty column from the e^T C residual, and the magnitude from either.
"""

import jax.numpy as jnp
import numpy as np


def gemm(a, b):
    """C = A @ B in f32 — the semantic baseline for everything."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------
def encode_a(a):
    """A -> A^c: append the column-sum row e^T A (eq. 1)."""
    return jnp.vstack([a, jnp.sum(a, axis=0, keepdims=True)])


def encode_b(b):
    """B -> B^r: append the row-sum column B e (eq. 2)."""
    return jnp.hstack([b, jnp.sum(b, axis=1, keepdims=True)])


def full_checksum_product(a, b):
    """C^f = A^c B^r (eq. 3) — the (M+1) x (N+1) checksummed product."""
    return gemm(encode_a(a), encode_b(b))


def row_checksum(c):
    """C e — per-row sums (the paper's C^r)."""
    return jnp.sum(c, axis=1)


def col_checksum(c):
    """e^T C — per-column sums (the paper's C^c)."""
    return jnp.sum(c, axis=0)


# ---------------------------------------------------------------------------
# Sub-tile checksums: the unified view of thread/warp/threadblock-level ABFT.
# A (sm, sn) granularity partitions C into (M/sm, N/sn) sub-tiles, each
# carrying its own row/col checksums (thread level: sm,sn = m_t,n_t; warp:
# m_w,n_w; threadblock: m_tb,n_tb).
# ---------------------------------------------------------------------------
def subtile_row_checksums(c, sm, sn):
    """(M/sm, sm, N/sn): row sums within each (sm, sn) sub-tile."""
    m, n = c.shape
    return c.reshape(m // sm, sm, n // sn, sn).sum(axis=3)


def subtile_col_checksums(c, sm, sn):
    """(M/sm, N/sn, sn): column sums within each (sm, sn) sub-tile."""
    m, n = c.shape
    return c.reshape(m // sm, sm, n // sn, sn).sum(axis=1)


# ---------------------------------------------------------------------------
# Injection + detection/correction oracle
# ---------------------------------------------------------------------------
def apply_injections(c, injections):
    """Apply additive SEU offsets (the paper's §5.3 protocol) to a C matrix.

    injections: iterable of (row, col, magnitude) in *global* coordinates.
    """
    c = np.asarray(c).copy()
    for r, col, mag in injections:
        c[int(r), int(col)] += mag
    return jnp.asarray(c)


def detect_and_correct(c_faulty, cr, cc, rel=1e-4, abs_=1e-3):
    """Offline single-error detect + correct over a full matrix given carried
    checksums cr = (true C) e and cc = e^T (true C).

    Returns (corrected C, n_corrected). Mirrors the in-kernel logic at
    threadblock granularity but for whole matrices — used to cross-check the
    kernels and by the rust host-side re-verification tests.
    """
    c = np.asarray(c_faulty).astype(np.float64)
    cr = np.asarray(cr, dtype=np.float64)
    cc = np.asarray(cc, dtype=np.float64)
    dr = c.sum(axis=1) - cr
    dc = c.sum(axis=0) - cc
    tr = rel * (np.abs(c).sum(axis=1) + np.abs(cr)) + abs_
    tc = rel * (np.abs(c).sum(axis=0) + np.abs(cc)) + abs_
    row_bad = np.abs(dr) > tr
    col_bad = np.abs(dc) > tc
    n = 0
    if row_bad.any() and col_bad.any():
        r = int(np.argmax(np.abs(dr)))
        col = int(np.argmax(np.abs(dc)))
        c[r, col] -= dr[r]
        n = 1
    return jnp.asarray(c.astype(np.float32)), n


# ---------------------------------------------------------------------------
# Ding et al. 2011 non-fused outer-product ABFT oracle (the baseline the
# paper compares against in Figs 12-16). The output matrix is accumulated
# over a series of (M x K_s) @ (K_s x N) products; checksums are verified
# after every step.
# ---------------------------------------------------------------------------
def ding_outer_product(a, b, ks):
    """Reference for the non-fused pipeline: returns the final C^f after
    accumulating K/ks encoded outer-product steps."""
    m, k = a.shape
    _, n = b.shape
    ac = encode_a(a)  # (M+1, K)
    br = encode_b(b)  # (K, N+1)
    cf = jnp.zeros((m + 1, n + 1), dtype=jnp.float32)
    for s in range(0, k, ks):
        cf = cf + gemm(ac[:, s : s + ks], br[s : s + ks, :])
    return cf


def ding_verify(cf, rel=1e-4, abs_=1e-3):
    """Check the C^f invariants: C row sums vs the checksum column, C column
    sums vs the checksum row. Returns (row_residual, col_residual, ok)."""
    c = cf[:-1, :-1]
    dr = jnp.sum(c, axis=1) - cf[:-1, -1]
    dc = jnp.sum(c, axis=0) - cf[-1, :-1]
    tr = rel * (jnp.sum(jnp.abs(c), axis=1) + jnp.abs(cf[:-1, -1])) + abs_
    tc = rel * (jnp.sum(jnp.abs(c), axis=0) + jnp.abs(cf[-1, :-1])) + abs_
    ok = (jnp.abs(dr) <= tr).all() & (jnp.abs(dc) <= tc).all()
    return dr, dc, ok


# ---------------------------------------------------------------------------
# FLOP accounting (shared with gpusim; keep formulas in sync with
# rust/src/gpusim/kernel_model.rs)
# ---------------------------------------------------------------------------
def gemm_flops(m, n, k):
    return 2.0 * m * n * k


def checksum_encode_flops(m, n, k, sm, sn):
    """Extra FLOPs for maintaining sub-tile checksums at (sm, sn)
    granularity: encoding e^T A and B e per sub-tile row/column band plus the
    two rank-update products (paper §4.2.2: thread level costs 2/n_t of the
    GEMM; this generalizes that ratio)."""
    enc = k * (n / sn) + k * (m / sm)
    acc = 2.0 * m * k * (n / sn) + 2.0 * n * k * (m / sm)
    return enc + acc
