"""Template-based code generation for (FT-)GEMM Pallas kernels.

This module is the reproduction of the paper's §3.2 + §4.3 contribution: a
single parameterized template that, given the 7 Table-1 tile parameters and
an optional fault-tolerance level, *generates* a high-performance kernel for
a concrete input shape. The CUDA template emits SIMT code; ours emits a
Pallas kernel (see DESIGN.md §Hardware-Adaptation for the mapping):

    threadblock tile (m_tb, n_tb, k_tb) -> pallas grid program + BlockSpec
    warp tile (m_w, n_w)                -> checksum sub-tile granularity
    thread tile (m_t, n_t)              -> micro-tile (register block)

Fused online ABFT (§4.2, unified across the three levels): the kernel
maintains per-sub-tile row/column checksums updated *from the input
operands* each k-step (so they always reflect the true product), injects
SEU offsets into the accumulator when requested, and every
``verify_every`` k-steps recomputes the accumulator's sub-tile sums,
compares against the carried checksums, locates the faulty element (row
from the C·e residual, column from the eᵀ·C residual) and subtracts the
offset — detection *and* correction fully inside the kernel, no extra
memory passes (the "fully-fused" property the paper claims over Kosaian &
Rashmi '21).

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the rust
runtime runs natively. Real-TPU performance is *modeled* (rust/src/gpusim),
never measured from these binaries.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .params import MAX_INJ, VERIFY_EVERY, KernelParams

# Detection thresholds: residuals are compared against
#   rel * (|recomputed sums| + |carried checksum|) + abs
# which tracks f32 accumulation drift (different summation orders between
# the checksum path and the row/col sums of the accumulator).
DEFAULT_REL = 1e-4
DEFAULT_ABS = 1e-3


def _check_divisible(m, n, k, p: KernelParams):
    p.validate()
    if m % p.m_tb or n % p.n_tb or k % p.k_tb:
        raise ValueError(
            f"shape ({m},{n},{k}) not divisible by tile ({p.m_tb},{p.n_tb},{p.k_tb})"
        )


# ---------------------------------------------------------------------------
# Plain GEMM template (§3.1 endpoint: tiled + k-pipelined)
# ---------------------------------------------------------------------------
def make_gemm(m: int, n: int, k: int, p: KernelParams):
    """Generate the non-FT SGEMM kernel: 3-D grid (i, j, s) with the k
    dimension innermost and accumulating (the outer-product k-loop of
    Fig 2); A/B tiles stream HBM->VMEM per BlockSpec (the double-buffered
    prefetch of §3.1.7 is the TPU pipeline's job once the schedule is
    expressed this way)."""
    _check_divisible(m, n, k, p)

    def kernel(a_ref, b_ref, c_ref):
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            c_ref[...] = jnp.zeros(c_ref.shape, jnp.float32)

        c_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    grid = (m // p.m_tb, n // p.n_tb, k // p.k_tb)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p.m_tb, p.k_tb), lambda i, j, s: (i, s)),
            pl.BlockSpec((p.k_tb, p.n_tb), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((p.m_tb, p.n_tb), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )

    def gemm(a, b):
        return (fn(a, b),)

    return gemm


# ---------------------------------------------------------------------------
# Fused FT-GEMM template (§4.2: thread / warp / threadblock level unified)
# ---------------------------------------------------------------------------
def make_ft_gemm(
    m: int,
    n: int,
    k: int,
    p: KernelParams,
    level: str = "tb",
    correct: bool = True,
    verify_every: int = VERIFY_EVERY,
    max_inj: int = MAX_INJ,
    rel: float = DEFAULT_REL,
    abs_: float = DEFAULT_ABS,
):
    """Generate a fused fault-tolerant SGEMM kernel.

    level   : 'thread' | 'warp' | 'tb' — checksum granularity (paper §4.2.1-3)
    correct : True = online ABFT (detect + correct in-kernel, §4.2);
              False = detect-only / offline ABFT (§5.5) — the coordinator
              must recompute on detection.

    Inputs : A (m,k) f32, B (k,n) f32, inj (max_inj, 4) f32 rows of
             [global_row, global_col, k_step, magnitude]; magnitude 0 ⇒ slot
             unused, so the same artifact serves fault-free and injected runs.
    Outputs: C (m,n); CR (gm,gn,S_m,sm,S_n) carried row checksums;
             CC (gm,gn,S_m,S_n,sn) carried col checksums; ERR (gm,gn) count
             of detected(-and-corrected) errors per tile.
    """
    _check_divisible(m, n, k, p)
    sm, sn = p.sub_tile(level)
    S_m, S_n = p.m_tb // sm, p.n_tb // sn
    gm, gn, gk = m // p.m_tb, n // p.n_tb, k // p.k_tb
    m_tb, n_tb, k_tb = p.m_tb, p.n_tb, p.k_tb

    def kernel(a_ref, b_ref, inj_ref, c_ref, cr_ref, cc_ref, err_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        s = pl.program_id(2)
        nk = pl.num_programs(2)

        @pl.when(s == 0)
        def _init():
            c_ref[...] = jnp.zeros(c_ref.shape, jnp.float32)
            cr_ref[...] = jnp.zeros(cr_ref.shape, jnp.float32)
            cc_ref[...] = jnp.zeros(cc_ref.shape, jnp.float32)
            err_ref[...] = jnp.zeros(err_ref.shape, jnp.float32)

        a = a_ref[...]  # (m_tb, k_tb)
        b = b_ref[...]  # (k_tb, n_tb)
        partial = jnp.dot(a, b, preferred_element_type=jnp.float32)

        # --- SEU injection (paper §5.3: additive offset on the accumulator
        # register). Injection rows that fall outside this (i, j, s) program
        # are masked to zero magnitude.
        inj = inj_ref[...]
        rows = inj[:, 0].astype(jnp.int32)
        cols = inj[:, 1].astype(jnp.int32)
        steps = inj[:, 2].astype(jnp.int32)
        mags = inj[:, 3]
        here = (
            (rows >= i * m_tb)
            & (rows < (i + 1) * m_tb)
            & (cols >= j * n_tb)
            & (cols < (j + 1) * n_tb)
            & (steps == s)
        )
        mags = jnp.where(here, mags, 0.0)
        lr = jnp.clip(rows - i * m_tb, 0, m_tb - 1)
        lc = jnp.clip(cols - j * n_tb, 0, n_tb - 1)
        row_oh = (lr[:, None] == jnp.arange(m_tb)[None, :]).astype(jnp.float32)
        col_oh = (lc[:, None] == jnp.arange(n_tb)[None, :]).astype(jnp.float32)
        fault = jnp.einsum("e,em,en->mn", mags, row_oh, col_oh)

        acc = c_ref[...] + partial + fault

        # --- checksum maintenance from the INPUT operands (never from acc),
        # fused with the operand tiles already resident in VMEM — this is
        # the paper's key fusion: e^T A and B e cost one extra reduction
        # over data the prefetch stage already loaded (§4.2.3, Fig 5a).
        a3 = a.reshape(S_m, sm, k_tb)
        b3 = b.reshape(k_tb, S_n, sn)
        row_enc = b3.sum(axis=2)  # (k_tb, S_n)  = B e per column band
        col_enc = a3.sum(axis=1)  # (S_m, k_tb)  = e^T A per row band
        cr = cr_ref[0, 0] + jnp.einsum("aik,kb->aib", a3, row_enc)  # (S_m,sm,S_n)
        cc = cc_ref[0, 0] + jnp.einsum("ak,kbj->abj", col_enc, b3)  # (S_m,S_n,sn)

        # --- verification (+ correction) every verify_every k-steps and on
        # the final step: the "error detection and correction period" of the
        # SEU fault model (§4.1).
        def verify(args):
            acc, nerr = args
            c4 = acc.reshape(S_m, sm, S_n, sn)
            rsum = c4.sum(axis=3)  # (S_m, sm, S_n)
            csum = c4.sum(axis=1)  # (S_m, S_n, sn)
            dr = rsum - cr
            dc = csum - cc
            thr_r = rel * (jnp.abs(c4).sum(axis=3) + jnp.abs(cr)) + abs_
            thr_c = rel * (jnp.abs(c4).sum(axis=1) + jnp.abs(cc)) + abs_
            bad_r = jnp.abs(dr) > thr_r
            bad_c = jnp.abs(dc) > thr_c
            det = bad_r.any(axis=1) & bad_c.any(axis=2)  # (S_m, S_n)
            nerr = nerr + jnp.where(det, 1.0, 0.0).sum()
            if not correct:
                return acc, nerr
            # locate: row index from the C·e residual, column index from the
            # e^T·C residual; magnitude is the residual itself (Fig 3e).
            r_idx = jnp.argmax(jnp.abs(dr), axis=1)  # (S_m, S_n)
            c_idx = jnp.argmax(jnp.abs(dc), axis=2)  # (S_m, S_n)
            mag = jnp.take_along_axis(dr, r_idx[:, None, :], axis=1)[:, 0, :]
            mag = jnp.where(det, mag, 0.0)  # (S_m, S_n)
            roh = (
                jnp.arange(sm)[None, :, None] == r_idx[:, None, :]
            )  # (S_m, sm, S_n)
            coh = (
                jnp.arange(sn)[None, None, :] == c_idx[:, :, None]
            )  # (S_m, S_n, sn)
            fix = (
                mag[:, None, :, None]
                * roh[:, :, :, None].astype(jnp.float32)
                * coh[:, None, :, :].astype(jnp.float32)
            )
            return (c4 - fix).reshape(m_tb, n_tb), nerr

        do_verify = ((s + 1) % verify_every == 0) | (s == nk - 1)
        acc, nerr = jax.lax.cond(
            do_verify, verify, lambda args: args, (acc, err_ref[0, 0])
        )

        c_ref[...] = acc
        cr_ref[0, 0] = cr
        cc_ref[0, 0] = cc
        err_ref[...] = nerr.reshape(1, 1)

    grid = (gm, gn, gk)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_tb, k_tb), lambda i, j, s: (i, s)),
            pl.BlockSpec((k_tb, n_tb), lambda i, j, s: (s, j)),
            pl.BlockSpec((max_inj, 4), lambda i, j, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m_tb, n_tb), lambda i, j, s: (i, j)),
            pl.BlockSpec((1, 1, S_m, sm, S_n), lambda i, j, s: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, S_m, S_n, sn), lambda i, j, s: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, s: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn, S_m, sm, S_n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn, S_m, S_n, sn), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ],
        interpret=True,
    )

    def ft_gemm(a, b, inj):
        c, cr, cc, err = fn(a, b, inj)
        return c, cr, cc, err

    return ft_gemm


# ---------------------------------------------------------------------------
# VMEM footprint / MXU-utilization estimate (the L1 "profile" — interpret
# mode has no TPU timings, so perf is reasoned structurally; DESIGN.md §Perf)
# ---------------------------------------------------------------------------
def vmem_bytes(p: KernelParams, level: str | None = None, max_inj: int = MAX_INJ):
    """Bytes of VMEM a program instance holds: A tile + B tile + C tile
    (+ checksums + injection table for FT variants), f32, double-buffered
    operands (the pipeline keeps 2 in-flight operand tiles)."""
    operand = 2 * (p.m_tb * p.k_tb + p.k_tb * p.n_tb) * 4
    acc = p.m_tb * p.n_tb * 4
    total = operand + acc
    if level is not None:
        sm, sn = p.sub_tile(level)
        S_m, S_n = p.m_tb // sm, p.n_tb // sn
        total += (S_m * sm * S_n + S_m * S_n * sn) * 4  # carried checksums
        total += max_inj * 4 * 4  # injection table
        total += (p.k_tb * S_n + S_m * p.k_tb) * 4  # encodings
    return total


def mxu_flops_ratio(p: KernelParams, level: str | None = None):
    """Fraction of a program's FLOPs that land on the MXU (the dot) vs the
    VPU (checksum reductions). 1.0 for the plain kernel."""
    dot = 2.0 * p.m_tb * p.n_tb * p.k_tb
    if level is None:
        return 1.0
    sm, sn = p.sub_tile(level)
    S_m, S_n = p.m_tb // sm, p.n_tb // sn
    extra = (
        p.k_tb * S_n * sn  # row_enc reduction
        + S_m * sm * p.k_tb  # col_enc reduction
        + 2.0 * p.m_tb * p.k_tb * S_n  # cr update
        + 2.0 * p.n_tb * p.k_tb * S_m  # cc update
    )
    return dot / (dot + extra)
