"""Kernel parameterization — the paper's Table 1.

The code-generation template (template.py) takes the 7 tile parameters the
paper uses for its SGEMM codegen (§3.2.1):

    m_tb, n_tb, k_tb : threadblock-level tile     (grid program tile on TPU)
    m_w,  n_w        : warp-level tile            (checksum sub-tile on TPU)
    m_t,  n_t        : thread-level tile          (micro-tile / register block)

plus FT-related parameters that the paper bakes into its FT-SGEMM template
(§4.3): the fault-tolerance granularity level and the verification interval.

Table 1 presets (T4) are reproduced verbatim; the same presets drive both
the python codegen and the rust-side selection heuristic + gpusim model
(rust/src/codegen/params.rs mirrors this table — keep them in sync).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class KernelParams:
    """The 7 codegen parameters of the paper's SGEMM template (Table 1)."""

    m_tb: int
    n_tb: int
    k_tb: int
    m_w: int
    n_w: int
    m_t: int
    n_t: int

    def validate(self) -> None:
        """Divisibility constraints the CUDA template needs (warp layout,
        vectorized loads) and that our Pallas template needs (sub-tile
        reshapes)."""
        if self.m_tb % self.m_w or self.n_tb % self.n_w:
            raise ValueError(f"warp tile must divide threadblock tile: {self}")
        if self.m_w % self.m_t or self.n_w % self.n_t:
            raise ValueError(f"thread tile must divide warp tile: {self}")
        for v in (self.m_tb, self.n_tb, self.k_tb, self.m_w, self.n_w, self.m_t, self.n_t):
            if v <= 0 or (v & (v - 1)) != 0:
                raise ValueError(f"tile sizes must be positive powers of two: {self}")

    @property
    def warps_per_block(self) -> int:
        return (self.m_tb // self.m_w) * (self.n_tb // self.n_w)

    @property
    def threads_per_block(self) -> int:
        # In CUDA terms: each thread owns an m_t x n_t micro-tile.
        return (self.m_tb // self.m_t) * (self.n_tb // self.n_t)

    def sub_tile(self, level: str):
        """Checksum granularity for an FT level (paper §4.2):
        thread-level ABFT verifies per m_t x n_t micro-tile, warp-level per
        m_w x n_w sub-tile, threadblock-level per full m_tb x n_tb tile."""
        if level == "thread":
            return self.m_t, self.n_t
        if level == "warp":
            return self.m_w, self.n_w
        if level == "tb":
            return self.m_tb, self.n_tb
        raise ValueError(f"unknown FT level {level!r}")

    def to_dict(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------------
# Table 1: SGEMM kernel parameter setup on a Tesla T4 GPU (verbatim).
# ---------------------------------------------------------------------------
TABLE1: dict[str, KernelParams] = {
    "small": KernelParams(16, 16, 16, 8, 16, 2, 2),
    "medium": KernelParams(32, 32, 8, 16, 32, 4, 4),
    "large": KernelParams(64, 64, 8, 32, 64, 8, 8),
    "tall": KernelParams(32, 128, 8, 16, 64, 4, 8),  # "tall and skinny"
    "huge": KernelParams(128, 128, 8, 32, 64, 8, 8),
}


def select_class(m: int, n: int, k: int) -> str:
    """The paper's semi-empirical shape-class heuristic (§3.2.2): the four
    square-ish classes split at 128/256/512, plus `tall` for strongly
    rectangular outputs (one output dim >= 4x the other)."""
    lo, hi = sorted((m, n))
    if hi >= 4 * lo and hi >= 128:
        return "tall"
    size = max(m, n)
    if size <= 128:
        return "small"
    if size <= 256:
        return "medium"
    if size <= 512:
        return "large"
    return "huge"


def select_params(m: int, n: int, k: int) -> KernelParams:
    return TABLE1[select_class(m, n, k)]


# ---------------------------------------------------------------------------
# Artifact shape buckets: HLO is fixed-shape, so the AOT pipeline lowers one
# kernel per (class, concrete bucket shape); the rust router pads requests up
# to the bucket. Buckets are chosen so each class's preset parameters divide
# the bucket dims exactly.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Bucket:
    name: str
    m: int
    n: int
    k: int
    params: KernelParams = field(compare=False)

    def __post_init__(self):
        p = self.params
        p.validate()
        if self.m % p.m_tb or self.n % p.n_tb or self.k % p.k_tb:
            raise ValueError(f"bucket {self.name} not divisible by its tile params")


BUCKETS: dict[str, Bucket] = {
    "small": Bucket("small", 64, 64, 64, TABLE1["small"]),
    "medium": Bucket("medium", 128, 128, 128, TABLE1["medium"]),
    "large": Bucket("large", 256, 256, 256, TABLE1["large"]),
    "tall": Bucket("tall", 128, 512, 256, TABLE1["tall"]),
    "huge": Bucket("huge", 512, 512, 512, TABLE1["huge"]),
}

# Fused-FT kernels track up to MAX_INJ injected errors per execution; the
# injection descriptor is a dense (MAX_INJ, 6) f32 input (see template.py).
MAX_INJ = 8

# Default verification interval (in k-steps): checksums are *updated* every
# k_tb step; verification + correction fire every VERIFY_EVERY steps and on
# the final step. This is the paper's "error detection and correction
# period" (§4.1) — SEU is assumed per interval, matching Ding's K_s protocol
# in the Fig 16 comparison.
VERIFY_EVERY = 8
