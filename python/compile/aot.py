"""AOT pipeline: lower every registry variant to HLO text + manifest.

Python runs ONCE, here. The interchange format is HLO *text*, not a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly.

    cd python && python -m compile.aot --out ../artifacts

writes  <out>/<name>.hlo.txt        one per ArtifactSpec
        <out>/manifest.json         shapes + roles + metadata for rust

Lowering goes through stablehlo -> XlaComputation with return_tuple=True;
the rust runtime unwraps the tuple (Literal::to_tuple).
"""

import argparse
import hashlib
import json
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .model import REGISTRY, ArtifactSpec


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: ArtifactSpec) -> str:
    lowered = jax.jit(spec.fn).lower(*spec.args)
    return to_hlo_text(lowered)


def _shape_entry(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def _out_shapes(spec: ArtifactSpec) -> list[dict]:
    outs = jax.eval_shape(spec.fn, *spec.args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    assert len(outs) == len(spec.outputs), (
        f"{spec.name}: {len(outs)} outputs but {len(spec.outputs)} roles"
    )
    return [
        {"role": role, **_shape_entry(o)} for role, o in zip(spec.outputs, outs)
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="AOT-lower all kernel variants")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated subset of names")
    args = ap.parse_args(argv)

    import os

    os.makedirs(args.out, exist_ok=True)
    names = list(REGISTRY) if args.only is None else args.only.split(",")

    manifest = {"format": 1, "artifacts": []}
    t_all = time.time()
    for name in names:
        spec = REGISTRY[name]
        t0 = time.time()
        hlo = lower_spec(spec)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(hlo)
        entry = {
            "name": name,
            "file": fname,
            "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            "inputs": [_shape_entry(a) for a in spec.args],
            "outputs": _out_shapes(spec),
            "meta": spec.meta,
        }
        manifest["artifacts"].append(entry)
        print(f"  {name:28s} {len(hlo)/1024:8.1f} KiB  {time.time()-t0:5.1f}s")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"{len(names)} artifacts in {time.time()-t_all:.1f}s -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
