# Build orchestration for the two-language stack.
#
#   make artifacts   lower every kernel variant to HLO text (python/JAX, runs once)
#   make build       release build of the rust serving stack
#   make test        tier-1 gate: cargo build --release && cargo test -q
#   make bench       hot-path benchmarks (writes BENCH_pipeline.json)
#
# The rust stack runs WITHOUT artifacts too: the engine falls back to the
# built-in manifest + reference backend (see DESIGN.md "Substitutions").

ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts build test bench lint clean serve loadgen

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	cargo build --release
	cargo test -q

bench:
	cargo bench --bench hotpath
	cargo bench --bench ablation

lint:
	cargo fmt --check
	cargo clippy --all-targets

clean:
	rm -rf target figures_out

# TCP gateway on the sample config's [serve] address (127.0.0.1:7421).
serve:
	cargo run --release -- serve --config ftgemm.toml

# Closed-loop load harness against a running `make serve` gateway.
loadgen:
	cargo run --release --bin loadgen -- --addr 127.0.0.1:7421 \
	    --clients 8 --requests 200 --sweep-clients 1,2,4,8
